//! MNIST Neural ODE experiment driver — paper §4.1.1 (Table 1, Figure 3).
//!
//! Paper setting: B=512, Momentum(0.1, 0.9) + InvDecay(1e-5), 75 epochs,
//! coef_e annealed 100 -> 10, coef_s = 0.0285, STEER b = 0.5, TayNODE K=3
//! with lambda = 3.02e-3.  This driver reproduces the grid at testbed scale
//! (synthetic MNIST, B=32, epochs from `TrainOpts`).

use anyhow::Result;

use crate::coordinator::budget::BudgetRouter;
use crate::coordinator::method::Method;
use crate::coordinator::metrics::{EpochAccumulator, RunResult};
use crate::coordinator::schedule::{ExpAnneal, InvDecay};
use crate::coordinator::steer::EndTimeSampler;
use crate::data::{batcher::Batcher, mnist_synth};
use crate::runtime::state::{Metrics, TrainState};
use crate::runtime::{Backend, StepCoefs, TrainData};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

pub const MODEL: &str = "mnist_node";
const BATCH: usize = 32;

pub struct Coefficients {
    pub lr: InvDecay,
    pub coef_e: Option<ExpAnneal>,
    pub coef_s: f64,
    /// Sampled-step local regularization coefficient (LRNODE).
    pub coef_l: f64,
    pub coef_aux: f64,
    pub steer: Option<EndTimeSampler>,
}

/// Resolve the paper's coefficients for a method from the backend's hyper
/// block (shared with mnist_nsde where noted).
pub fn coefficients(backend: &dyn Backend, method: Method, epochs: usize) -> Result<Coefficients> {
    let h = backend.model(MODEL)?.hyper;
    let get = |k: &str| -> f64 { h.get(k).copied().unwrap_or(0.0) };
    Ok(Coefficients {
        lr: InvDecay {
            lr0: get("lr"),
            gamma: get("inv_decay"),
        },
        coef_e: method.er.then(|| ExpAnneal {
            start: get("coef_e_start"),
            end: get("coef_e_end"),
            total_epochs: epochs,
        }),
        coef_s: if method.sr { get("coef_s") } else { 0.0 },
        coef_l: if method.lr { get("coef_l") } else { 0.0 },
        coef_aux: if method.taynode { get("taylor_coef") } else { 0.0 },
        steer: method.steer.then(|| EndTimeSampler {
            t_nominal: get("t1"),
            b: get("steer_b"),
        }),
    })
}

pub fn run(backend: &dyn Backend, method: Method, opts: super::TrainOpts) -> Result<RunResult> {
    run_with(backend, method, opts, None)
}

/// [`run`] continuing from a checkpointed training position
/// (`opts.epochs` = additional epochs; see `super::ResumeState`).
pub fn run_with(
    backend: &dyn Backend,
    method: Method,
    opts: super::TrainOpts,
    resume: Option<&super::ResumeState>,
) -> Result<RunResult> {
    let info = backend.model(MODEL)?;
    let epoch0 = resume.map_or(0, |r| r.epochs_done);
    // Schedules anneal over the whole run's epoch target — completed
    // epochs included, the checkpointed target preferred — so a resumed
    // run sees the same coefficient at epoch e as the original.
    let coefs = coefficients(backend, method, super::schedule_epochs(resume, opts.epochs))?;

    // Data: synthetic MNIST (DESIGN.md §4 substitution).
    let n_train = (opts.iters_per_epoch * BATCH).max(BATCH * 4);
    let train = mnist_synth::generate(n_train, opts.seed);
    let test = mnist_synth::generate(BATCH * 4, opts.seed ^ 0xDEAD);
    let train_onehot = mnist_synth::one_hot(&train.labels);
    let test_onehot = mnist_synth::one_hot(&test.labels);

    let mut router = BudgetRouter::new(backend.ladder(MODEL, method.taynode)?)?;
    let mut state = TrainState::new(
        backend.init_params(MODEL, opts.seed as u32)?,
        info.opt_state_size,
    );
    let mut rng = Rng::new(opts.seed ^ 0x7EED);
    let mut batcher = Batcher::new(train.n, BATCH, opts.seed);

    if let Some(r) = resume {
        super::apply_resume(&mut state, &mut router, r)?;
    }
    // Fast-forward the batch order and RNG streams past the completed
    // epochs, replaying the exact per-iteration call order of the
    // training loop (batch draw, optional STEER draw, seed draw).
    for _ in 0..epoch0 * opts.iters_per_epoch {
        let _ = batcher.next_batch();
        if let Some(s) = &coefs.steer {
            let _ = s.sample(&mut rng);
        }
        let _ = rng.next_u32();
    }

    // Pre-compile every rung + the predict path so the stopwatch measures
    // steady-state training, not PJRT JIT (native: no-op).
    backend.warm(MODEL, method.taynode)?;

    let mut sw = Stopwatch::new();
    let mut epochs_out = Vec::with_capacity(opts.epochs);
    let (mut bx, mut by) = (Vec::new(), Vec::new());

    for epoch in epoch0..epoch0 + opts.epochs {
        let mut acc = EpochAccumulator::default();
        let epoch_t0 = std::time::Instant::now();
        sw.start();
        for _ in 0..opts.iters_per_epoch {
            let idx = batcher.next_batch().to_vec();
            Batcher::gather(&train.images, mnist_synth::DIM, &idx, &mut bx);
            Batcher::gather(&train_onehot, mnist_synth::CLASSES, &idx, &mut by);
            let step = StepCoefs {
                lr: coefs.lr.at(state.iter) as f32,
                coef_e: coefs.coef_e.map_or(0.0, |a| a.at(epoch)) as f32,
                coef_s: coefs.coef_s as f32,
                coef_l: coefs.coef_l as f32,
                coef_aux: coefs.coef_aux as f32,
                t1: coefs.steer.as_ref().map_or(1.0, |s| s.sample(&mut rng)),
                seed: rng.next_u32(),
                ..Default::default()
            };
            let m = super::routed_step(
                backend,
                MODEL,
                method.taynode,
                &mut router,
                &mut state,
                &TrainData::Classify { x: &bx, y: &by },
                &step,
            )?;
            acc.push(&m);
        }
        sw.stop();
        anyhow::ensure!(state.is_finite(), "parameters diverged at epoch {epoch}");
        let rec = acc.finish(epoch, epoch_t0.elapsed().as_secs_f64(), router.rung());
        if opts.verbose {
            println!(
                "[{}] epoch {epoch}: loss {:.4} acc {:.3} nfe {:.1} rung {} ({:.1}s)",
                method.label(false),
                rec.loss,
                rec.metric,
                rec.nfe,
                rec.rung,
                rec.wall_s
            );
        }
        epochs_out.push(rec);
    }

    // Prediction timing + held-out metrics via the early-exiting path.
    let eval = |images: &[f32], onehot: &[f32]| -> Result<(Metrics, f64)> {
        let mut ms = Vec::new();
        let mut secs = Vec::new();
        for b in 0..images.len() / (BATCH * mnist_synth::DIM) {
            let xs = &images[b * BATCH * mnist_synth::DIM..(b + 1) * BATCH * mnist_synth::DIM];
            let ys = &onehot
                [b * BATCH * mnist_synth::CLASSES..(b + 1) * BATCH * mnist_synth::CLASSES];
            let t0 = std::time::Instant::now();
            let (_, m) = backend.predict(
                MODEL,
                &state.params,
                &TrainData::Classify { x: xs, y: ys },
                4242,
            )?;
            secs.push(t0.elapsed().as_secs_f64());
            ms.push(m);
        }
        let n = ms.len().max(1) as f64;
        let avg = Metrics {
            loss: ms.iter().map(|m| m.loss).sum::<f64>() / n,
            metric: ms.iter().map(|m| m.metric).sum::<f64>() / n,
            nfe: ms.iter().map(|m| m.nfe).sum::<f64>() / n,
            ..Default::default()
        };
        Ok((avg, secs.iter().sum::<f64>() / n))
    };
    let (train_eval, _) = eval(
        &train.images[..BATCH * 4 * mnist_synth::DIM],
        &train_onehot[..BATCH * 4 * mnist_synth::CLASSES],
    )?;
    let (test_eval, pred_s) = eval(&test.images, &test_onehot)?;

    Ok(RunResult {
        experiment: "table1_mnist_node".into(),
        method: method.label(false),
        seed: opts.seed,
        epochs: epochs_out,
        train_time_s: sw.total_secs(),
        predict_time_s: pred_s,
        predict_nfe: test_eval.nfe,
        final_train_metric: train_eval.metric,
        final_test_metric: test_eval.metric,
        final_train_loss: train_eval.loss,
        final_test_loss: test_eval.loss,
        escalations: router.escalations,
        descents: router.descents,
        final_opt_state: state.opt_state,
        final_iter: state.iter,
        final_rung: router.rung(),
        final_window: router.window().to_vec(),
        epochs_done: epoch0 + opts.epochs,
        final_params: state.params,
    })
}
