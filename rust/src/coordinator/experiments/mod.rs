//! Experiment drivers — one per paper experiment.
//!
//! Each driver owns its dataset, wires the method grid (coefficients,
//! schedules, STEER sampling, budget-ladder routing) into the lowered
//! artifacts and produces [`RunResult`]s that the bench harness turns into
//! the paper's tables and figures.

pub mod latent_ode;
pub mod mnist_node;
pub mod mnist_nsde;
pub mod spiral_node;
pub mod spiral_nsde;

use anyhow::Result;

use super::Method;
use crate::runtime::Engine;

/// Common knobs for a training run (scaled-down defaults; the paper's
/// epoch counts are listed in each driver's docs).
#[derive(Clone, Copy, Debug)]
pub struct TrainOpts {
    pub epochs: usize,
    /// Optimizer iterations per epoch.
    pub iters_per_epoch: usize,
    /// Replica seed (data order, init, STEER and SDE noise).
    pub seed: u64,
    /// Print per-epoch progress lines.
    pub verbose: bool,
}

impl Default for TrainOpts {
    fn default() -> Self {
        Self {
            epochs: 3,
            iters_per_epoch: 10,
            seed: 0,
            verbose: false,
        }
    }
}

/// Dispatch an experiment by name (CLI entry point).
pub fn run_by_name(
    engine: &Engine,
    experiment: &str,
    method: Method,
    opts: TrainOpts,
) -> Result<super::RunResult> {
    match experiment {
        "mnist-node" => mnist_node::run(engine, method, opts),
        "latent-ode" | "physionet" => latent_ode::run(engine, method, opts),
        "spiral-node" => spiral_node::run(engine, method, opts),
        "spiral-nsde" => spiral_nsde::run(engine, method, opts),
        "mnist-nsde" => mnist_nsde::run(engine, method, opts),
        other => anyhow::bail!(
            "unknown experiment {other:?} (mnist-node|latent-ode|spiral-node|\
             spiral-nsde|mnist-nsde)"
        ),
    }
}
