//! Experiment drivers — one per paper experiment.
//!
//! Each driver owns its dataset and wires the method grid (coefficients,
//! schedules, STEER sampling, budget-ladder routing) into a [`Backend`] —
//! the native discrete-adjoint trainer by default, the PJRT artifact
//! engine behind the `pjrt` feature — and produces [`RunResult`]s that
//! the bench harness turns into the paper's tables and figures.

pub mod latent_ode;
pub mod mnist_node;
pub mod mnist_nsde;
pub mod spiral_node;
pub mod spiral_nsde;

use anyhow::Result;

use super::budget::BudgetRouter;
use super::Method;
use crate::runtime::state::{Metrics, TrainState};
use crate::runtime::{Backend, StepCoefs, TrainData};
use crate::solvers::error::SolveErrorKind;

/// Common knobs for a training run (scaled-down defaults; the paper's
/// epoch counts are listed in each driver's docs).
#[derive(Clone, Copy, Debug)]
pub struct TrainOpts {
    pub epochs: usize,
    /// Optimizer iterations per epoch.
    pub iters_per_epoch: usize,
    /// Replica seed (data order, init, STEER and SDE noise).
    pub seed: u64,
    /// Print per-epoch progress lines.
    pub verbose: bool,
}

impl Default for TrainOpts {
    fn default() -> Self {
        Self {
            epochs: 3,
            iters_per_epoch: 10,
            seed: 0,
            verbose: false,
        }
    }
}

/// Mid-run training position restored from a checkpoint v2 train block
/// (`serve::checkpoint::TrainProgress`) — everything a driver needs to
/// continue a run bit-identically to the uninterrupted one: the
/// committed parameters, the Adam moments, the optimizer iteration (lr
/// decay position), the budget-ladder rung + descent window, and how
/// many epochs already ran (drivers fast-forward their RNG/batcher
/// streams past them).  Resume assumes the same experiment, method,
/// seed and `--iters` as the original run (DESIGN.md §Distributed).
#[derive(Clone, Debug, Default)]
pub struct ResumeState {
    pub params: Vec<f32>,
    /// Empty = fresh zeros (v1 checkpoints carry no optimizer state).
    pub opt_state: Vec<f32>,
    pub iter: u64,
    pub rung: usize,
    /// Budget-router descent-evidence window at save time.
    pub window: Vec<f64>,
    pub epochs_done: usize,
    /// Total-epoch target the original run's epoch-annealed schedules
    /// (`ExpAnneal`) were built over (checkpoint v2 `train.total_epochs`;
    /// 0 = unrecorded, as in v1 files).  See [`schedule_epochs`].
    pub total_epochs: usize,
}

/// Total-epoch horizon a (possibly resumed) run's epoch-annealed
/// schedules span.  The recorded target wins while it still covers the
/// requested span — so a continuation of an interrupted run reuses the
/// original coefficient schedule bit-for-bit — and extending past the
/// target (or resuming without one) anneals over the actual
/// `epochs_done + additional` span (DESIGN.md §Distributed, "Checkpoint
/// resume").
pub fn schedule_epochs(resume: Option<&ResumeState>, additional: usize) -> usize {
    let span = resume.map_or(0, |r| r.epochs_done) + additional;
    resume.map_or(0, |r| r.total_epochs).max(span)
}

/// Install a [`ResumeState`] into a fresh driver's state + router.
pub(crate) fn apply_resume(
    state: &mut TrainState,
    router: &mut BudgetRouter,
    resume: &ResumeState,
) -> Result<()> {
    anyhow::ensure!(
        resume.params.len() == state.params.len(),
        "checkpoint has {} parameters, model wants {}",
        resume.params.len(),
        state.params.len()
    );
    state.params = resume.params.clone();
    if !resume.opt_state.is_empty() {
        anyhow::ensure!(
            resume.opt_state.len() == state.opt_state.len(),
            "checkpoint has {} optimizer values, model wants {}",
            resume.opt_state.len(),
            state.opt_state.len()
        );
        state.opt_state = resume.opt_state.clone();
    }
    state.iter = resume.iter;
    router.restore(resume.rung, &resume.window)
}

/// One budget-ladder-routed train step: run on the router's rung, retry
/// the same batch on escalation (a truncated solve's gradients are
/// biased, so its candidate state is discarded), commit otherwise.
///
/// Failure routing keys off the typed [`Metrics::error`] kind
/// (DESIGN.md §Robustness):
///
/// * `BudgetExhausted` — the solve was merely truncated; escalate to the
///   next rung and retry the batch (the historical behavior).
/// * any other kind (`NonFiniteState`, `StepSizeUnderflow`, ...) — the
///   vector field is diverging, which no budget can fix: the batch is
///   **skipped** (candidate state discarded, parameters untouched,
///   rung unchanged) instead of burning every rung on it and committing
///   a NaN update.  Training continues on the next batch.
pub(crate) fn routed_step(
    backend: &dyn Backend,
    model: &str,
    tay: bool,
    router: &mut BudgetRouter,
    state: &mut TrainState,
    data: &TrainData,
    coefs: &StepCoefs,
) -> Result<Metrics> {
    loop {
        let out = backend.train_step(model, tay, router.rung(), state, data, coefs)?;
        if matches!(out.metrics.error, Some(kind) if kind != SolveErrorKind::BudgetExhausted) {
            router.note_skip();
            return Ok(out.metrics);
        }
        if router.observe(
            out.metrics.naccept + out.metrics.nreject,
            out.metrics.success,
        ) {
            continue;
        }
        state.update(out.params, out.opt_state)?;
        return Ok(out.metrics);
    }
}

/// Dispatch an experiment by name (CLI entry point).
pub fn run_by_name(
    backend: &dyn Backend,
    experiment: &str,
    method: Method,
    opts: TrainOpts,
) -> Result<super::RunResult> {
    run_by_name_resumed(backend, experiment, method, opts, None)
}

/// [`run_by_name`] continuing from a checkpointed training position
/// (`--resume`); `opts.epochs` counts the *additional* epochs to run.
pub fn run_by_name_resumed(
    backend: &dyn Backend,
    experiment: &str,
    method: Method,
    opts: TrainOpts,
    resume: Option<&ResumeState>,
) -> Result<super::RunResult> {
    match experiment {
        "mnist-node" => mnist_node::run_with(backend, method, opts, resume),
        "latent-ode" | "physionet" => latent_ode::run_with(backend, method, opts, resume),
        "spiral-node" => spiral_node::run_with(backend, method, opts, resume),
        "spiral-nsde" => spiral_nsde::run_with(backend, method, opts, resume),
        "mnist-nsde" => mnist_nsde::run_with(backend, method, opts, resume),
        other => anyhow::bail!(
            "unknown experiment {other:?} (mnist-node|latent-ode|spiral-node|\
             spiral-nsde|mnist-nsde)"
        ),
    }
}

/// Backend model name behind an experiment id (what `--checkpoint`
/// exports through `Backend::export_state`).
pub fn model_for(experiment: &str) -> Result<&'static str> {
    Ok(match experiment {
        "mnist-node" => mnist_node::MODEL,
        "latent-ode" | "physionet" => latent_ode::MODEL,
        "spiral-node" => spiral_node::MODEL,
        "spiral-nsde" => spiral_nsde::MODEL,
        "mnist-nsde" => mnist_nsde::MODEL,
        other => anyhow::bail!("unknown experiment {other:?}"),
    })
}

/// The fixed serving grid a trajectory experiment's checkpoint carries
/// (`serve::batcher` coalesces requests over it).  Empty for experiments
/// whose predict output is not a single trajectory.
pub fn serving_grid(experiment: &str) -> Vec<f32> {
    match experiment {
        "spiral-node" => spiral_node::ground_truth().1,
        _ => Vec::new(),
    }
}
