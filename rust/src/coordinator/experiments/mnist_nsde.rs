//! MNIST Neural SDE driver — paper §4.2.2 (Table 4, Figure 6).
//!
//! Paper setting: B=512, Adam(0.01) + InvDecay(1e-5), 40 epochs, constant
//! coef_e = 10.0 / coef_s = 0.1, prediction = mean logits over 10 driving
//! paths.  Testbed scale: synthetic MNIST, B=32.

use anyhow::{Context, Result};

use crate::coordinator::budget::BudgetRouter;
use crate::coordinator::method::Method;
use crate::coordinator::metrics::{EpochAccumulator, RunResult};
use crate::coordinator::schedule::InvDecay;
use crate::data::{batcher::Batcher, mnist_synth};
use crate::runtime::state::{Metrics, TrainState};
use crate::runtime::{Engine, Input};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

pub const MODEL: &str = "mnist_nsde";
const BATCH: usize = 32;

pub fn run(engine: &Engine, method: Method, opts: super::TrainOpts) -> Result<RunResult> {
    let spec = engine.manifest.model(MODEL)?.clone();
    let h = &spec.hyper;
    let get = |k: &str| -> f64 { *h.get(k).unwrap_or(&0.0) };
    let lr = InvDecay {
        lr0: get("lr"),
        gamma: get("inv_decay"),
    };
    let ce = if method.er { get("coef_e") } else { 0.0 };
    let cs = if method.sr { get("coef_s") } else { 0.0 };

    let n_train = (opts.iters_per_epoch * BATCH).max(BATCH * 4);
    let train = mnist_synth::generate(n_train, opts.seed);
    let test = mnist_synth::generate(BATCH * 2, opts.seed ^ 0xDEAD);
    let train_onehot = mnist_synth::one_hot(&train.labels);
    let test_onehot = mnist_synth::one_hot(&test.labels);

    let ladder: Vec<_> = engine
        .manifest
        .train_ladder(MODEL, false)
        .into_iter()
        .cloned()
        .collect();
    let mut router = BudgetRouter::new(
        ladder.iter().map(|a| a.budget.unwrap_or(usize::MAX)).collect(),
    )?;

    let mut state = TrainState::new(
        engine.init_params(MODEL, opts.seed as u32)?,
        spec.opt_state_size,
    );
    let mut rng = Rng::new(opts.seed ^ 0x51DE);
    let mut batcher = Batcher::new(train.n, BATCH, opts.seed);

    // Pre-compile every rung + the predict artifact so the stopwatch
    // measures steady-state training, not PJRT JIT.
    for art in &ladder {
        engine.load(&art.name)?;
    }
    engine.load(&format!("{MODEL}_predict"))?;

    let mut sw = Stopwatch::new();
    let mut epochs_out = Vec::with_capacity(opts.epochs);
    let (mut bx, mut by) = (Vec::new(), Vec::new());

    for epoch in 0..opts.epochs {
        let mut acc = EpochAccumulator::default();
        let t0 = std::time::Instant::now();
        sw.start();
        for _ in 0..opts.iters_per_epoch {
            let idx = batcher.next_batch().to_vec();
            Batcher::gather(&train.images, mnist_synth::DIM, &idx, &mut bx);
            Batcher::gather(&train_onehot, mnist_synth::CLASSES, &idx, &mut by);
            let lr_t = lr.at(state.iter) as f32;
            let seed = rng.next_u32();
            loop {
                let art = &ladder[router.rung()];
                let out = engine
                    .run_spec(
                        art,
                        &[
                            Input::F32(&state.params),
                            Input::F32(&state.opt_state),
                            Input::F32(&bx),
                            Input::F32(&by),
                            Input::Scalar(lr_t),
                            Input::Scalar(ce as f32),
                            Input::Scalar(cs as f32),
                            Input::SeedU32(seed),
                        ],
                    )
                    .with_context(|| format!("train step on {}", art.name))?;
                let [params, opt_state, metrics]: [Vec<f32>; 3] =
                    out.try_into().ok().context("train step arity")?;
                let m = Metrics::decode(&metrics)?;
                if router.observe(m.naccept + m.nreject, m.success) {
                    continue;
                }
                state.update(params, opt_state)?;
                acc.push(&m);
                break;
            }
        }
        sw.stop();
        anyhow::ensure!(state.is_finite(), "parameters diverged at epoch {epoch}");
        let rec = acc.finish(epoch, t0.elapsed().as_secs_f64(), router.rung());
        if opts.verbose {
            println!(
                "[{}] epoch {epoch}: loss {:.4} acc {:.3} nfe {:.1} rung {} ({:.1}s)",
                method.label(true),
                rec.loss,
                rec.metric,
                rec.nfe,
                rec.rung,
                rec.wall_s
            );
        }
        epochs_out.push(rec);
    }

    // Evaluation: 10-trajectory mean-logit prediction (inside the artifact).
    let eval = |images: &[f32], onehot: &[f32], batches: usize| -> Result<(Metrics, f64)> {
        let mut ms = Vec::new();
        let mut secs = Vec::new();
        for b in 0..batches {
            let xs = &images[b * BATCH * mnist_synth::DIM..(b + 1) * BATCH * mnist_synth::DIM];
            let ys = &onehot
                [b * BATCH * mnist_synth::CLASSES..(b + 1) * BATCH * mnist_synth::CLASSES];
            let t0 = std::time::Instant::now();
            let out = engine.run(
                &format!("{MODEL}_predict"),
                &[
                    Input::F32(&state.params),
                    Input::F32(xs),
                    Input::F32(ys),
                    Input::SeedU32(4242),
                ],
            )?;
            secs.push(t0.elapsed().as_secs_f64());
            ms.push(Metrics::decode(&out[1])?);
        }
        let n = ms.len().max(1) as f64;
        Ok((
            Metrics {
                loss: ms.iter().map(|m| m.loss).sum::<f64>() / n,
                metric: ms.iter().map(|m| m.metric).sum::<f64>() / n,
                nfe: ms.iter().map(|m| m.nfe).sum::<f64>() / n,
                ..Default::default()
            },
            secs.iter().sum::<f64>() / n,
        ))
    };
    engine.load(&format!("{MODEL}_predict"))?;
    let (train_eval, _) = eval(&train.images, &train_onehot, 2)?;
    let (test_eval, pred_s) = eval(&test.images, &test_onehot, 2)?;

    Ok(RunResult {
        experiment: "table4_mnist_nsde".into(),
        method: method.label(true),
        seed: opts.seed,
        epochs: epochs_out,
        train_time_s: sw.total_secs(),
        predict_time_s: pred_s,
        predict_nfe: test_eval.nfe,
        final_train_metric: train_eval.metric,
        final_test_metric: test_eval.metric,
        final_train_loss: train_eval.loss,
        final_test_loss: test_eval.loss,
        escalations: router.escalations,
        descents: router.descents,
    })
}
