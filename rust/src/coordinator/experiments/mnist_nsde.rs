//! MNIST Neural SDE driver — paper §4.2.2 (Table 4, Figure 6).
//!
//! Paper setting: B=512, Adam(0.01) + InvDecay(1e-5), 40 epochs, constant
//! coef_e = 10.0 / coef_s = 0.1, prediction = mean logits over several
//! driving paths.  Testbed scale: synthetic MNIST, B=32.

use anyhow::Result;

use crate::coordinator::budget::BudgetRouter;
use crate::coordinator::method::Method;
use crate::coordinator::metrics::{EpochAccumulator, RunResult};
use crate::coordinator::schedule::InvDecay;
use crate::data::{batcher::Batcher, mnist_synth};
use crate::runtime::state::{Metrics, TrainState};
use crate::runtime::{Backend, StepCoefs, TrainData};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

pub const MODEL: &str = "mnist_nsde";
const BATCH: usize = 32;

pub fn run(backend: &dyn Backend, method: Method, opts: super::TrainOpts) -> Result<RunResult> {
    run_with(backend, method, opts, None)
}

/// [`run`] continuing from a checkpointed training position
/// (`opts.epochs` = additional epochs; see `super::ResumeState`).
pub fn run_with(
    backend: &dyn Backend,
    method: Method,
    opts: super::TrainOpts,
    resume: Option<&super::ResumeState>,
) -> Result<RunResult> {
    let info = backend.model(MODEL)?;
    let get = |k: &str| -> f64 { info.hyper.get(k).copied().unwrap_or(0.0) };
    let lr = InvDecay {
        lr0: get("lr"),
        gamma: get("inv_decay"),
    };
    let ce = if method.er { get("coef_e") } else { 0.0 };
    let cs = if method.sr { get("coef_s") } else { 0.0 };
    let cl = if method.lr { get("coef_l") } else { 0.0 };

    let n_train = (opts.iters_per_epoch * BATCH).max(BATCH * 4);
    let train = mnist_synth::generate(n_train, opts.seed);
    let test = mnist_synth::generate(BATCH * 2, opts.seed ^ 0xDEAD);
    let train_onehot = mnist_synth::one_hot(&train.labels);
    let test_onehot = mnist_synth::one_hot(&test.labels);

    let mut router = BudgetRouter::new(backend.ladder(MODEL, false)?)?;
    let mut state = TrainState::new(
        backend.init_params(MODEL, opts.seed as u32)?,
        info.opt_state_size,
    );
    let mut rng = Rng::new(opts.seed ^ 0x51DE);
    let mut batcher = Batcher::new(train.n, BATCH, opts.seed);

    let epoch0 = resume.map_or(0, |r| r.epochs_done);
    if let Some(r) = resume {
        super::apply_resume(&mut state, &mut router, r)?;
    }
    // Fast-forward the batch order and the per-iteration seed stream
    // past the completed epochs, in the exact per-iteration call order
    // the training loop uses.
    for _ in 0..epoch0 * opts.iters_per_epoch {
        let _ = batcher.next_batch();
        let _ = rng.next_u32();
    }

    backend.warm(MODEL, false)?;

    let mut sw = Stopwatch::new();
    let mut epochs_out = Vec::with_capacity(opts.epochs);
    let (mut bx, mut by) = (Vec::new(), Vec::new());

    for epoch in epoch0..epoch0 + opts.epochs {
        let mut acc = EpochAccumulator::default();
        let t0 = std::time::Instant::now();
        sw.start();
        for _ in 0..opts.iters_per_epoch {
            let idx = batcher.next_batch().to_vec();
            Batcher::gather(&train.images, mnist_synth::DIM, &idx, &mut bx);
            Batcher::gather(&train_onehot, mnist_synth::CLASSES, &idx, &mut by);
            let step = StepCoefs {
                lr: lr.at(state.iter) as f32,
                coef_e: ce as f32,
                coef_s: cs as f32,
                coef_l: cl as f32,
                seed: rng.next_u32(),
                ..Default::default()
            };
            let m = super::routed_step(
                backend,
                MODEL,
                false,
                &mut router,
                &mut state,
                &TrainData::Classify { x: &bx, y: &by },
                &step,
            )?;
            acc.push(&m);
        }
        sw.stop();
        anyhow::ensure!(state.is_finite(), "parameters diverged at epoch {epoch}");
        let rec = acc.finish(epoch, t0.elapsed().as_secs_f64(), router.rung());
        if opts.verbose {
            println!(
                "[{}] epoch {epoch}: loss {:.4} acc {:.3} nfe {:.1} rung {} ({:.1}s)",
                method.label(true),
                rec.loss,
                rec.metric,
                rec.nfe,
                rec.rung,
                rec.wall_s
            );
        }
        epochs_out.push(rec);
    }

    // Evaluation: mean-logit prediction over several driving paths.
    let eval = |images: &[f32], onehot: &[f32], batches: usize| -> Result<(Metrics, f64)> {
        let mut ms = Vec::new();
        let mut secs = Vec::new();
        for b in 0..batches {
            let xs = &images[b * BATCH * mnist_synth::DIM..(b + 1) * BATCH * mnist_synth::DIM];
            let ys = &onehot
                [b * BATCH * mnist_synth::CLASSES..(b + 1) * BATCH * mnist_synth::CLASSES];
            let t0 = std::time::Instant::now();
            let (_, m) = backend.predict(
                MODEL,
                &state.params,
                &TrainData::Classify { x: xs, y: ys },
                4242,
            )?;
            secs.push(t0.elapsed().as_secs_f64());
            ms.push(m);
        }
        let n = ms.len().max(1) as f64;
        Ok((
            Metrics {
                loss: ms.iter().map(|m| m.loss).sum::<f64>() / n,
                metric: ms.iter().map(|m| m.metric).sum::<f64>() / n,
                nfe: ms.iter().map(|m| m.nfe).sum::<f64>() / n,
                ..Default::default()
            },
            secs.iter().sum::<f64>() / n,
        ))
    };
    let (train_eval, _) = eval(&train.images, &train_onehot, 2)?;
    let (test_eval, pred_s) = eval(&test.images, &test_onehot, 2)?;

    Ok(RunResult {
        experiment: "table4_mnist_nsde".into(),
        method: method.label(true),
        seed: opts.seed,
        epochs: epochs_out,
        train_time_s: sw.total_secs(),
        predict_time_s: pred_s,
        predict_nfe: test_eval.nfe,
        final_train_metric: train_eval.metric,
        final_test_metric: test_eval.metric,
        final_train_loss: train_eval.loss,
        final_test_loss: test_eval.loss,
        escalations: router.escalations,
        descents: router.descents,
        final_opt_state: state.opt_state,
        final_iter: state.iter,
        final_rung: router.rung(),
        final_window: router.window().to_vec(),
        epochs_done: epoch0 + opts.epochs,
        final_params: state.params,
    })
}
