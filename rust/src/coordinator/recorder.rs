//! Run recording: JSON run records + CSV epoch series under `runs/`.
//!
//! The figure benches (Figs 1, 3, 4, 6) re-read these records to print
//! their series, so every training run leaves a machine-readable trace.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::metrics::RunResult;
use crate::util::json::Json;

pub struct Recorder {
    dir: PathBuf,
}

impl Recorder {
    pub fn new(dir: impl AsRef<Path>) -> Result<Recorder> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir).with_context(|| format!("creating {dir:?}"))?;
        Ok(Recorder { dir })
    }

    fn slug(s: &str) -> String {
        s.chars()
            .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect()
    }

    /// Write `<exp>__<method>__s<seed>.json` and the matching `.csv`.
    pub fn save(&self, r: &RunResult) -> Result<PathBuf> {
        let base = format!(
            "{}__{}__s{}",
            Self::slug(&r.experiment),
            Self::slug(&r.method),
            r.seed
        );
        let json_path = self.dir.join(format!("{base}.json"));
        fs::write(&json_path, r.to_json().to_string_pretty())?;
        let mut csv = String::from(
            "epoch,loss,metric,nfe,naccept,nreject,r_e,r_e2,r_s,r_l,wall_s,rung\n",
        );
        for e in &r.epochs {
            csv.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{},{}\n",
                e.epoch,
                e.loss,
                e.metric,
                e.nfe,
                e.naccept,
                e.nreject,
                e.r_e,
                e.r_e2,
                e.r_s,
                e.r_l,
                e.wall_s,
                e.rung
            ));
        }
        fs::write(self.dir.join(format!("{base}.csv")), csv)?;
        Ok(json_path)
    }

    /// Load every run record for an experiment.
    pub fn load_experiment(&self, experiment: &str) -> Result<Vec<Json>> {
        let prefix = format!("{}__", Self::slug(experiment));
        let mut out = Vec::new();
        if !self.dir.exists() {
            return Ok(out);
        }
        for entry in fs::read_dir(&self.dir)? {
            let path = entry?.path();
            let name = path.file_name().unwrap().to_string_lossy().to_string();
            if name.starts_with(&prefix) && name.ends_with(".json") {
                out.push(Json::parse(&fs::read_to_string(&path)?)?);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::EpochRecord;

    fn sample_run(method: &str, seed: u64) -> RunResult {
        RunResult {
            experiment: "Table 1".into(),
            method: method.into(),
            seed,
            epochs: vec![EpochRecord {
                epoch: 0,
                loss: 1.0,
                nfe: 100.0,
                ..Default::default()
            }],
            train_time_s: 5.0,
            predict_time_s: 0.05,
            predict_nfe: 200.0,
            final_train_metric: 0.9,
            final_test_metric: 0.8,
            final_train_loss: 0.3,
            final_test_loss: 0.4,
            escalations: 0,
            descents: 0,
            final_params: Vec::new(),
        }
    }

    #[test]
    fn save_and_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("regnde-rec-{}", std::process::id()));
        let rec = Recorder::new(&dir).unwrap();
        rec.save(&sample_run("ERNODE", 1)).unwrap();
        rec.save(&sample_run("Vanilla NODE", 2)).unwrap();
        let runs = rec.load_experiment("Table 1").unwrap();
        assert_eq!(runs.len(), 2);
        let methods: Vec<&str> = runs
            .iter()
            .map(|r| r.get("method").unwrap().as_str().unwrap())
            .collect();
        assert!(methods.contains(&"ERNODE"));
        // csv written too
        assert!(dir.join("table_1__ernode__s1.csv").exists());
        fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn load_missing_dir_is_empty() {
        let rec = Recorder {
            dir: PathBuf::from("/nonexistent/regnde"),
        };
        assert!(rec.load_experiment("x").unwrap().is_empty());
    }
}
