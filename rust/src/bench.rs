//! Shared bench harness for the table/figure regeneration targets.
//!
//! Each `cargo bench` target runs a (method x seed) grid of scaled-down
//! training runs through the coordinator and renders the corresponding
//! paper table/figure rows (`util::tablefmt`).  Scale knobs come from env
//! vars so `cargo bench` stays tractable by default but can be pushed
//! toward paper scale:
//!
//!   REGNDE_BENCH_EPOCHS / REGNDE_BENCH_ITERS / REGNDE_BENCH_SEEDS

use anyhow::Result;

use crate::coordinator::experiments::{run_by_name, TrainOpts};
use crate::coordinator::recorder::Recorder;
use crate::coordinator::{Method, RunResult};
use crate::runtime::Backend;
use crate::util::stats::Summary;
use crate::util::tablefmt::Table;

pub struct BenchConfig {
    pub epochs: usize,
    pub iters: usize,
    pub seeds: Vec<u64>,
}

impl BenchConfig {
    /// Read scale knobs from the environment (defaults keep a full table
    /// bench in the minutes range on this CPU testbed).
    pub fn from_env(default_epochs: usize, default_iters: usize) -> Self {
        let n_seeds = crate::util::cli::env_usize("REGNDE_BENCH_SEEDS", 2);
        Self {
            epochs: crate::util::cli::env_usize("REGNDE_BENCH_EPOCHS", default_epochs),
            iters: crate::util::cli::env_usize("REGNDE_BENCH_ITERS", default_iters),
            seeds: (0..n_seeds as u64).collect(),
        }
    }
}

/// All runs for one method over the replica seeds.
pub struct MethodRuns {
    pub method: Method,
    pub runs: Vec<RunResult>,
}

impl MethodRuns {
    pub fn summary(&self, f: impl Fn(&RunResult) -> f64) -> Summary {
        Summary::of(&self.runs.iter().map(f).collect::<Vec<_>>())
    }
}

/// Run the full (method x seed) grid for an experiment, recording runs.
/// Model name behind each experiment (for artifact warm-up).
fn model_of(experiment: &str) -> &'static str {
    match experiment {
        "mnist-node" => "mnist_node",
        "latent-ode" | "physionet" => "latent_ode",
        "spiral-node" => "spiral_node",
        "spiral-nsde" => "spiral_nsde",
        "mnist-nsde" => "mnist_nsde",
        _ => "",
    }
}

pub fn run_grid(
    experiment: &str,
    methods: &[Method],
    cfg: &BenchConfig,
) -> Result<Vec<MethodRuns>> {
    // Backend selected via REGNDE_BACKEND (default: native).
    let backend = crate::runtime::backend_from_env(&crate::default_artifacts_dir())?;
    let recorder = Recorder::new(crate::default_runs_dir())?;
    // Pre-compile every ladder rung of this experiment's model so the
    // first method's train timer doesn't absorb PJRT JIT cost.
    let model = model_of(experiment);
    if !model.is_empty() {
        backend.warm(model, false)?;
        if methods.iter().any(|m| m.taynode) {
            backend.warm(model, true)?;
        }
    }
    let mut out = Vec::new();
    for &method in methods {
        let mut runs = Vec::new();
        for &seed in &cfg.seeds {
            let opts = TrainOpts {
                epochs: cfg.epochs,
                iters_per_epoch: cfg.iters,
                seed,
                verbose: false,
            };
            let r = run_by_name(backend.as_ref(), experiment, method, opts)?;
            crate::log_info!(
                "bench",
                "[{}] seed {seed}: train {:.1}s predict {:.4}s nfe {:.1}",
                r.method, r.train_time_s, r.predict_time_s, r.predict_nfe
            );
            recorder.save(&r)?;
            runs.push(r);
        }
        out.push(MethodRuns { method, runs });
    }
    Ok(out)
}

/// Render the paper-style summary table for a classification experiment
/// (Tables 1 and 4: accuracy columns) or a loss experiment (Tables 2/3).
pub fn render_table(
    title: &str,
    grid: &[MethodRuns],
    sde: bool,
    metric_is_accuracy: bool,
) -> String {
    let metric_cols: [&str; 2] = if metric_is_accuracy {
        ["Train Acc (%)", "Test Acc (%)"]
    } else {
        ["Train Loss", "Test Loss"]
    };
    let mut t = Table::new(
        title,
        &[
            "Method",
            metric_cols[0],
            metric_cols[1],
            "Train Time (s)",
            "Prediction Time (s)",
            "NFE",
        ],
    );
    let scale = if metric_is_accuracy { 100.0 } else { 1.0 };
    for m in grid {
        let tr = m.summary(|r| r.final_train_metric * scale);
        let te = m.summary(|r| r.final_test_metric * scale);
        let tt = m.summary(|r| r.train_time_s);
        let pt = m.summary(|r| r.predict_time_s);
        let nfe = m.summary(|r| r.predict_nfe);
        t.row(vec![
            m.method.label(sde),
            Table::pm(tr.mean, tr.std, 3),
            Table::pm(te.mean, te.std, 3),
            Table::pm(tt.mean, tt.std, 2),
            Table::pm(pt.mean, pt.std, 4),
            Table::pm(nfe.mean, nfe.std, 1),
        ]);
    }
    t.render()
}

/// Render an epoch-series figure (Figs 3/4/6) as aligned text columns.
pub fn render_series(title: &str, grid: &[MethodRuns], sde: bool) -> String {
    let mut out = format!("{title}\n");
    for m in grid {
        out.push_str(&format!("\n[{}]\n", m.method.label(sde)));
        out.push_str("  epoch |     loss |   metric |    NFE | rung\n");
        // average the per-epoch series across seeds
        let n_epochs = m.runs.iter().map(|r| r.epochs.len()).min().unwrap_or(0);
        for e in 0..n_epochs {
            let avg = |f: &dyn Fn(&crate::coordinator::EpochRecord) -> f64| -> f64 {
                m.runs.iter().map(|r| f(&r.epochs[e])).sum::<f64>() / m.runs.len() as f64
            };
            out.push_str(&format!(
                "  {:>5} | {:>8.4} | {:>8.4} | {:>6.1} | {:.1}\n",
                e,
                avg(&|r| r.loss),
                avg(&|r| r.metric),
                avg(&|r| r.nfe),
                avg(&|r| r.rung as f64),
            ));
        }
    }
    out
}

/// Fig-1-style aggregate: train/predict speedups of each method vs the
/// grid's first entry (the vanilla baseline).
pub fn render_speedups(title: &str, grid: &[MethodRuns], sde: bool) -> String {
    let base_t = grid[0].summary(|r| r.train_time_s).mean;
    let base_p = grid[0].summary(|r| r.predict_time_s).mean;
    let base_n = grid[0].summary(|r| r.predict_nfe).mean;
    let mut t = Table::new(
        title,
        &["Method", "Train Speedup", "Prediction Speedup", "NFE Ratio"],
    );
    for m in grid.iter().skip(1) {
        let tt = m.summary(|r| r.train_time_s).mean.max(1e-9);
        let pt = m.summary(|r| r.predict_time_s).mean.max(1e-9);
        let nf = m.summary(|r| r.predict_nfe).mean.max(1e-9);
        t.row(vec![
            m.method.label(sde),
            format!("{:.2}x", base_t / tt),
            format!("{:.2}x", base_p / pt),
            format!("{:.2}x", base_n / nf),
        ]);
    }
    t.render()
}
