//! Offline stub of the `xla` crate (xla-rs PJRT bindings).
//!
//! The build image has no network access and no prebuilt XLA/PJRT shared
//! library, so this vendored crate provides the exact API surface
//! `regnde::runtime::engine` consumes — enough for `cargo check --features
//! pjrt` to typecheck the whole PJRT path.  Every entry point that would
//! touch a real PJRT plugin returns [`Error::Unavailable`] at runtime.
//!
//! Production deployments swap this one dependency line in
//! `rust/Cargo.toml` for the real bindings
//! (`xla = { git = "https://github.com/LaurentMazare/xla-rs" }` plus
//! `XLA_EXTENSION_DIR`); no Rust source changes are required because the
//! signatures below mirror the real crate.

use std::fmt;

/// Stub error: the PJRT runtime is not linked into this build.
#[derive(Debug)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the real xla-rs PJRT bindings \
                 (this build vendors rust/vendor/xla, a typecheck-only stub)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the engine marshals (f32 tensors, u32 seeds).
pub trait NativeType: Copy + 'static {}
impl NativeType for f32 {}
impl NativeType for u32 {}

/// Host-side literal (dense tensor or tuple).
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal(())
    }

    pub fn scalar<T: NativeType>(_x: T) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unavailable("Literal::reshape"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed HLO module (text interchange format).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-side buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable bound to a client.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: AsRef<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}
