//! Whole-manifest smoke: every artifact compiles and executes once with
//! shape-correct synthetic inputs, and its outputs decode per the manifest.
//! Also failure-injection tests for the engine's input validation.
//!
//! Requires `--features pjrt`, real xla bindings and compiled artifacts.

#![cfg(feature = "pjrt")]

use regnde::runtime::{Engine, Input};

fn engine() -> Engine {
    Engine::new(regnde::default_artifacts_dir()).expect("artifacts built?")
}

#[test]
fn all_init_artifacts_produce_finite_params() {
    let e = engine();
    for model in ["mnist_node", "latent_ode", "spiral_node", "spiral_nsde", "mnist_nsde"] {
        let p = e.init_params(model, 3).unwrap();
        let expected = e.manifest.model(model).unwrap().params_size;
        assert_eq!(p.len(), expected, "{model}");
        assert!(p.iter().all(|v| v.is_finite()), "{model}");
        // glorot init: nonzero weights
        assert!(p.iter().any(|&v| v != 0.0), "{model}");
        // different seeds differ
        let p2 = e.init_params(model, 4).unwrap();
        assert_ne!(p, p2, "{model}");
        // same seed identical
        let p3 = e.init_params(model, 3).unwrap();
        assert_eq!(p, p3, "{model}");
    }
}

#[test]
fn wrong_input_count_is_rejected() {
    let e = engine();
    let err = e.run("mnist_node_predict", &[Input::SeedU32(1)]).unwrap_err();
    assert!(format!("{err:#}").contains("inputs"), "{err:#}");
}

#[test]
fn wrong_tensor_shape_is_rejected() {
    let e = engine();
    let bad = vec![0.0f32; 3];
    let x = vec![0.0f32; 32 * 784];
    let y = vec![0.0f32; 32 * 10];
    let err = e
        .run(
            "mnist_node_predict",
            &[Input::F32(&bad), Input::F32(&x), Input::F32(&y)],
        )
        .unwrap_err();
    assert!(format!("{err:#}").contains("elements"), "{err:#}");
}

#[test]
fn unknown_artifact_is_rejected() {
    let e = engine();
    assert!(e.run("nonexistent", &[]).is_err());
}

#[test]
fn predict_metrics_decode_and_success() {
    let e = engine();
    let params = e.init_params("mnist_node", 0).unwrap();
    let x = vec![0.5f32; 32 * 784];
    let mut y = vec![0.0f32; 32 * 10];
    for i in 0..32 {
        y[i * 10] = 1.0;
    }
    let out = e
        .run(
            "mnist_node_predict",
            &[Input::F32(&params), Input::F32(&x), Input::F32(&y)],
        )
        .unwrap();
    assert_eq!(out[0].len(), 32 * 10); // logits
    let m = regnde::runtime::Metrics::decode(&out[1]).unwrap();
    assert!(m.success);
    assert!(m.nfe >= 7.0);
    assert!((0.0..=1.0).contains(&m.metric));
}

#[test]
fn executable_cache_returns_same_instance() {
    let e = engine();
    let a = e.load("spiral_ode_solve").unwrap();
    let b = e.load("spiral_ode_solve").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
}

#[test]
fn train_ladder_budgets_strictly_ascend() {
    let e = engine();
    for model in ["mnist_node", "latent_ode", "spiral_node", "spiral_nsde", "mnist_nsde"] {
        let ladder = e.manifest.train_ladder(model, false);
        assert!(ladder.len() >= 2, "{model}");
        let budgets: Vec<_> = ladder.iter().map(|a| a.budget.unwrap()).collect();
        assert!(budgets.windows(2).all(|w| w[0] < w[1]), "{model}: {budgets:?}");
    }
}
