//! Distributed worker-failure suite (ISSUE 9 acceptance).
//!
//! Failure semantics under test (DESIGN.md §Distributed): a worker that
//! dies mid-epoch is detected by its transport failure, marked dead, and
//! its shard is **reassigned** to the next live worker in the fixed ring
//! — the recomputation is deterministic, so the epoch's bits are
//! unchanged.  When every worker has been tried for a shard, the step
//! fails with the typed [`DistError::WorkersExhausted`] — surfaced
//! through `train_step` and the experiment drivers as a normal error,
//! never a hang and never a panic.  Every remote read is
//! deadline-bounded, so the tests also assert wall-clock bounds.

use std::sync::Arc;
use std::time::{Duration, Instant};

use regnde::coordinator::experiments::{self, TrainOpts};
use regnde::coordinator::Method;
use regnde::dist::{DistBackend, DistError, RemoteOpts, Worker, WorkerHandle, WorkerOpts};
use regnde::runtime::{Backend, NativeBackend, StepCoefs, TrainData, TrainState};
use regnde::util::rng::Rng;

const IMG_DIM: usize = 784;
const CLASSES: usize = 10;

fn spawn_worker() -> WorkerHandle {
    Worker::spawn(
        Arc::new(NativeBackend::new()),
        WorkerOpts {
            read_timeout: Duration::from_millis(20),
            ..Default::default()
        },
        "127.0.0.1:0",
    )
    .expect("spawn loopback worker")
}

/// Short deadlines so a hang would fail the test quickly instead of
/// stalling the suite.
fn fast_opts() -> RemoteOpts {
    RemoteOpts {
        connect_timeout: Duration::from_millis(500),
        request_timeout: Duration::from_secs(30),
        read_tick: Duration::from_millis(10),
    }
}

fn classify_batch(b: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0f32; b * IMG_DIM];
    rng.fill_normal(&mut x, 0.5);
    let mut y = vec![0.0f32; b * CLASSES];
    for row in 0..b {
        y[row * CLASSES + rng.below(CLASSES)] = 1.0;
    }
    (x, y)
}

fn fresh_state(backend: &dyn Backend, model: &str) -> TrainState {
    let info = backend.model(model).expect("model info");
    TrainState {
        params: backend.init_params(model, 11).expect("init"),
        opt_state: vec![0.0; info.opt_state_size],
        iter: 0,
    }
}

/// Kill one of two workers between steps: the dead worker's shard is
/// reassigned to the survivor and training continues with bits equal to
/// an all-healthy (single-process) run.
#[test]
fn killed_worker_is_reassigned_and_bits_survive() {
    let w1 = spawn_worker();
    let w2 = spawn_worker();
    let workers = vec![w1.addr.to_string(), w2.addr.to_string()];

    let model = "mnist_node";
    let remote = DistBackend::remote(NativeBackend::new(), &workers, Some(2), fast_opts())
        .expect("remote backend");
    let reference = DistBackend::local(NativeBackend::new(), 2);

    let (x, y) = classify_batch(8, 0xFA17);
    let data = TrainData::Classify { x: &x, y: &y };
    let mut sr = fresh_state(&remote, model);
    let mut sl = fresh_state(&reference, model);

    let step = |n: u32| StepCoefs {
        lr: 0.05,
        seed: 7000 + n,
        ..Default::default()
    };

    // Each step's candidate state is committed, so post-failover bit
    // drift would compound into the final comparison.
    let commit = |sr: &mut TrainState, sl: &mut TrainState, n: u32, what: &str| {
        let out = remote
            .train_step(model, false, 0, sr, &data, &step(n))
            .unwrap_or_else(|e| panic!("{what}: {e:#}"));
        sr.update(out.params, out.opt_state).expect("commit remote");
        let out = reference
            .train_step(model, false, 0, sl, &data, &step(n))
            .expect("reference step");
        sl.update(out.params, out.opt_state).expect("commit reference");
    };

    // Step 0: both workers healthy.
    commit(&mut sr, &mut sl, 0, "healthy step");

    // Kill the second worker mid-epoch; the next step must reassign its
    // shard to the survivor, not fail and not hang.
    w2.kill();
    let t0 = Instant::now();
    commit(&mut sr, &mut sl, 1, "step after worker death (reassigned shard)");
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "reassignment stalled: {:?}",
        t0.elapsed()
    );

    // One more step on the surviving topology.
    commit(&mut sr, &mut sl, 2, "follow-up step");

    assert_eq!(sr.params.len(), sl.params.len());
    for (i, (a, b)) in sr.params.iter().zip(&sl.params).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "param {i} drifted after failover");
    }
    for (i, (a, b)) in sr.opt_state.iter().zip(&sl.opt_state).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "opt_state {i} drifted after failover");
    }

    w1.kill();
}

/// A failed worker is only skipped for the step that observed the
/// failure: once it is reachable again (here: restarted on the same
/// address) the next step's fresh connection attempt brings it back.
/// Sequence: kill w2 (step fails over to w1), restart w2, kill w1 —
/// the final step can only succeed through the revived w2.
#[test]
fn restarted_worker_rejoins_at_the_next_step() {
    let w1 = spawn_worker();
    let w2 = spawn_worker();
    let w2_addr = w2.addr.to_string();
    let workers = vec![w1.addr.to_string(), w2_addr.clone()];

    let model = "mnist_node";
    let remote = DistBackend::remote(NativeBackend::new(), &workers, Some(2), fast_opts())
        .expect("remote backend");
    let reference = DistBackend::local(NativeBackend::new(), 2);

    let (x, y) = classify_batch(8, 0xBEEF);
    let data = TrainData::Classify { x: &x, y: &y };
    let mut sr = fresh_state(&remote, model);
    let mut sl = fresh_state(&reference, model);

    let step = |n: u32| StepCoefs {
        lr: 0.05,
        seed: 9000 + n,
        ..Default::default()
    };
    let commit = |state: &mut TrainState, backend: &DistBackend, n: u32, what: &str| {
        let out = backend
            .train_step(model, false, 0, state, &data, &step(n))
            .unwrap_or_else(|e| panic!("{what}: {e:#}"));
        state.update(out.params, out.opt_state).expect("commit step");
    };

    commit(&mut sr, &remote, 0, "healthy step");
    commit(&mut sl, &reference, 0, "reference step");

    w2.kill();
    commit(&mut sr, &remote, 1, "failover step");
    commit(&mut sl, &reference, 1, "reference step");

    // Restart a worker on w2's address (kill() joins the accept loop
    // first, so the port is free; a short retry absorbs OS lag).
    let mut revived = None;
    for _ in 0..50 {
        match Worker::spawn(
            Arc::new(NativeBackend::new()),
            WorkerOpts {
                read_timeout: Duration::from_millis(20),
                ..Default::default()
            },
            &w2_addr,
        ) {
            Ok(h) => {
                revived = Some(h);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(100)),
        }
    }
    let revived = revived.expect("rebinding the killed worker's address");

    // With w1 gone, this step can only succeed if the coordinator
    // offers the previously-dead w2 a fresh connection.
    w1.kill();
    let t0 = Instant::now();
    commit(&mut sr, &remote, 2, "step through the revived worker");
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "revival stalled: {:?}",
        t0.elapsed()
    );
    commit(&mut sl, &reference, 2, "reference step");

    for (i, (a, b)) in sr.params.iter().zip(&sl.params).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "param {i} drifted through restart");
    }
    for (i, (a, b)) in sr.opt_state.iter().zip(&sl.opt_state).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "opt_state {i} drifted through restart");
    }
    revived.kill();
}

/// Every worker dead: the step fails with the typed
/// `DistError::WorkersExhausted` in bounded time — through an
/// established connection (worker dies under a live client) and again
/// on the already-dead topology.
#[test]
fn all_workers_dead_is_a_typed_error_not_a_hang() {
    let w1 = spawn_worker();
    let workers = vec![w1.addr.to_string()];

    let model = "spiral_node";
    let remote = DistBackend::remote(NativeBackend::new(), &workers, Some(1), fast_opts())
        .expect("remote backend");
    let (truth, ts) = experiments::spiral_node::ground_truth();
    let data = TrainData::Trajectory {
        data: &truth,
        ts: &ts,
    };
    let state = fresh_state(&remote, model);
    let coefs = StepCoefs {
        lr: 0.05,
        seed: 1,
        ..Default::default()
    };

    // Healthy first step establishes the persistent connection (its
    // candidate state is irrelevant here — the test is about failure).
    remote
        .train_step(model, false, 0, &state, &data, &coefs)
        .expect("healthy step");

    w1.kill();
    let t0 = Instant::now();
    let err = remote
        .train_step(model, false, 0, &state, &data, &coefs)
        .expect_err("step with every worker dead must fail");
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "failure took {:?} — deadline not enforced",
        t0.elapsed()
    );
    let dist = err
        .downcast_ref::<DistError>()
        .unwrap_or_else(|| panic!("expected DistError, got: {err:#}"));
    let DistError::WorkersExhausted { shard, workers, .. } = dist;
    assert_eq!(*shard, 0);
    assert_eq!(*workers, 1);

    // The topology stays dead: a retry is the same typed error, still
    // bounded, still no panic.
    let t1 = Instant::now();
    let err = remote
        .train_step(model, false, 0, &state, &data, &coefs)
        .expect_err("second step must also fail");
    assert!(err.downcast_ref::<DistError>().is_some(), "retry lost the typed error");
    assert!(t1.elapsed() < Duration::from_secs(60));
}

/// The typed error propagates through a full experiment driver (budget
/// router included) as an `Err`, not a panic or a stall.
#[test]
fn experiment_driver_surfaces_worker_exhaustion() {
    let w1 = spawn_worker();
    let workers = vec![w1.addr.to_string()];
    w1.kill();

    let remote = DistBackend::remote(NativeBackend::new(), &workers, Some(1), fast_opts())
        .expect("remote backend");
    let opts = TrainOpts {
        epochs: 1,
        iters_per_epoch: 1,
        seed: 0,
        verbose: false,
    };
    let t0 = Instant::now();
    let err = experiments::run_by_name(&remote, "spiral-node", Method::VANILLA, opts)
        .expect_err("training against a dead worker pool must fail");
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "driver stalled for {:?}",
        t0.elapsed()
    );
    let chain = format!("{err:#}");
    assert!(
        chain.contains("worker"),
        "error chain should name the worker exhaustion: {chain}"
    );
}
