//! Gradient checks for the sampled-step **local** regularization
//! objective (LRNODE / LRNSDE, Pal et al. 2023) on both solver stacks.
//!
//! The objective is one accepted step's error term `E_ĵ |h_ĵ|` of the
//! frozen discrete program (step sequence + Brownian increments fixed),
//! with ĵ reservoir-sampled by the `LocalReg` observer during the
//! forward solve.  The discrete adjoint applies the error cotangent at
//! exactly that step (`RegCoefs::local_e`); `ode_replay_errors` /
//! `sde_replay_errors` expose the per-step terms, so central finite
//! differences of entry ĵ are the ground truth the adjoint must match
//! (< 1e-4 relative, same bar as `tests/adjoint_gradcheck.rs`).

use regnde::solvers::adjoint::{
    ode_backward_sys, ode_replay, ode_replay_errors, sde_backward_sys, sde_replay,
    sde_replay_errors, OdeTape, RegCoefs, SdeTape,
};
use regnde::solvers::observer::{LocalReg, StepObserver};
use regnde::solvers::ode::{self, SolveOutcome};
use regnde::solvers::{sde, SolveOptions, SolveResultExt};
use regnde::solvers::{OdeSystem, OdeSystemVjp, Saveat, SdeSystem, SdeSystemVjp, StepBudget};

fn rel_err(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-12)
}

/// Nonlinear scalar dynamics dz/dt = sin(θ z): the error terms depend on
/// θ nontrivially at every step.
fn f(th: f64) -> impl Fn(&[f64], f64, &mut [f64]) {
    move |z: &[f64], _t: f64, dz: &mut [f64]| dz[0] = (th * z[0]).sin()
}

fn f_vjp(th: f64) -> impl FnMut(&[f64], f64, &[f64], &mut [f64], &mut [f64]) {
    move |z: &[f64], _t: f64, w: &[f64], gz: &mut [f64], gth: &mut [f64]| {
        let c = (th * z[0]).cos();
        gz[0] += w[0] * th * c;
        gth[0] += w[0] * z[0] * c;
    }
}

/// Taped grid solve through the unified driver with a total budget.
fn solve_taped<F: FnMut(&[f64], f64, &mut [f64])>(
    f: F,
    z0: &[f64],
    ts: &[f64],
    opts: &SolveOptions,
    total_budget: u64,
    tape: &mut OdeTape,
) -> (Vec<Vec<f64>>, SolveOutcome) {
    let mut sys = OdeSystem(f);
    let opts = opts.clone().with_budget(StepBudget::Total(total_budget));
    let (zs, out) = ode::drive(&mut sys, z0, Saveat::Grid(ts), &opts, Some(tape), &mut []);
    (zs, out.expect("taped gradcheck solve failed"))
}

#[test]
fn ode_sampled_step_gradient_matches_fd() {
    let theta = 1.3f64;
    let ts = [0.0, 0.5, 1.0];
    let opts = SolveOptions::new().with_tolerance(1e-6);
    let mut tape = OdeTape::new();
    let _ = solve_taped(f(theta), &[0.8], &ts, &opts, 100_000, &mut tape);
    assert!(tape.len() >= 3, "need a few steps to sample from");

    // Per-step terms sum (in order) to the replayed R_E, bit-for-bit.
    let errs = ode_replay_errors(&tape, &opts.tableau, &[0.8], f(theta));
    assert_eq!(errs.len(), tape.len());
    let (_, r_e, _) = ode_replay(&tape, &opts.tableau, &[0.8], f(theta));
    assert_eq!(errs.iter().sum::<f64>(), r_e, "per-step terms must sum to R_E");

    let save_grads = vec![vec![0.0]; ts.len()];
    let eps = 1e-4;
    for j in [0, tape.len() / 2, tape.len() - 1] {
        let mut gp = vec![0.0; 1];
        let mut sys = OdeSystemVjp {
            drift: f(theta),
            vjp: f_vjp(theta),
        };
        ode_backward_sys(
            &tape,
            &opts.tableau,
            &save_grads,
            &RegCoefs::global(0.0, 0.0).with_local(j, 1.0),
            &mut gp,
            &mut sys,
        );
        let term = |th: f64| ode_replay_errors(&tape, &opts.tableau, &[0.8], f(th))[j];
        let fd = (term(theta + eps) - term(theta - eps)) / (2.0 * eps);
        assert!(
            fd.abs() > 1e-12,
            "step {j}: term must depend on θ for the check to bite (fd={fd})"
        );
        assert!(
            rel_err(gp[0], fd) < 1e-4,
            "step {j}: adjoint {} vs fd {fd}",
            gp[0]
        );
    }
}

#[test]
fn ode_full_objective_with_local_term_matches_fd() {
    // data loss + 0.3·R_E + 0.2·R_S + 0.7·E_ĵ|h_ĵ| in one backward walk.
    let theta = 1.1f64;
    let ts = [0.0, 1.0];
    let opts = SolveOptions::new().with_tolerance(1e-6);
    let mut tape = OdeTape::new();
    let _ = solve_taped(f(theta), &[0.8], &ts, &opts, 100_000, &mut tape);
    assert!(tape.len() >= 2);
    let j = tape.len() / 2;
    let (coef_e, coef_s, coef_l) = (0.3, 0.2, 0.7);

    let mut gp = vec![0.0; 1];
    let mut sys = OdeSystemVjp {
        drift: f(theta),
        vjp: f_vjp(theta),
    };
    // L = z(t1) + regularizers: cotangent 1 at the last save point.
    let save_grads = vec![vec![0.0], vec![1.0]];
    ode_backward_sys(
        &tape,
        &opts.tableau,
        &save_grads,
        &RegCoefs::global(coef_e, coef_s).with_local(j, coef_l),
        &mut gp,
        &mut sys,
    );

    let objective = |th: f64| {
        let (saves, r_e, r_s) = ode_replay(&tape, &opts.tableau, &[0.8], f(th));
        let local = ode_replay_errors(&tape, &opts.tableau, &[0.8], f(th))[j];
        saves[1][0] + coef_e * r_e + coef_s * r_s + coef_l * local
    };
    let eps = 1e-5;
    let fd = (objective(theta + eps) - objective(theta - eps)) / (2.0 * eps);
    assert!(
        rel_err(gp[0], fd) < 1e-4,
        "full-objective adjoint {} vs fd {fd}",
        gp[0]
    );
}

#[test]
fn ode_local_reg_observer_samples_the_term_the_adjoint_differentiates() {
    // End-to-end coupling: the value LocalReg reports during the forward
    // drive is the sampled step's replayed error term (FSAL-stage
    // rounding only), so forward loss and backward cotangent agree.
    let theta = 0.9f64;
    let ts = [0.0, 0.5, 1.0];
    let mut sys = OdeSystem(f(theta));
    let mut tape = OdeTape::new();
    let mut local = LocalReg::new(17);
    let sopts = regnde::solvers::SolveOptions::new()
        .with_tolerance(1e-6)
        .with_budget(StepBudget::Total(100_000));
    let (_, out) = ode::drive(
        &mut sys,
        &[0.8],
        Saveat::Grid(&ts),
        &sopts,
        Some(&mut tape),
        &mut [&mut local],
    );
    assert!(out.is_ok(), "forward drive failed: {:?}", out.err());
    let j = local.sampled_step().expect("steps were accepted");
    assert!(j < tape.len());
    let errs = ode_replay_errors(&tape, &sopts.tableau, &[0.8], f(theta));
    assert!(
        (local.value() - errs[j]).abs() <= 1e-9 * errs[j].max(1e-12),
        "forward-sampled value {} vs replayed term {}",
        local.value(),
        errs[j]
    );
}

#[test]
fn sde_sampled_step_gradient_matches_fd() {
    let theta = 0.8f64;
    let sigma = 0.3f64;
    let drift = |th: f64| move |z: &[f64], _t: f64, dz: &mut [f64]| dz[0] = (th * z[0]).sin();
    let diffusion = move |_z: &[f64], _t: f64, dg: &mut [f64]| dg[0] = sigma;

    let mut rng = regnde::util::rng::Rng::new(5);
    let mut tape = SdeTape::new();
    let opts = SolveOptions::new()
        .with_tolerance(1e-2)
        .with_budget(StepBudget::Total(u64::MAX));
    let (stats, ok) = {
        let mut sys = SdeSystem {
            drift: drift(theta),
            diffusion,
        };
        let (_, outcome) = sde::drive(
            &mut sys,
            &[1.0],
            Saveat::Grid(&[0.0, 0.5, 1.0]),
            &mut rng,
            &opts,
            Some(&mut tape),
            &mut [],
        );
        (outcome.stats(), outcome.is_success())
    };
    assert!(ok && tape.len() >= 3, "need a few accepted steps");

    // Per-step terms sum (in order) to the replayed R_E, bit-for-bit.
    let errs = sde_replay_errors(&tape, &[1.0], drift(theta), diffusion);
    assert_eq!(errs.len(), tape.len());
    let (_, r_e, _) = sde_replay(&tape, &[1.0], drift(theta), diffusion);
    assert_eq!(errs.iter().sum::<f64>(), r_e);
    // And the replay reproduces the forward accumulator.
    assert!((r_e - stats.r_e).abs() <= 1e-12 * (1.0 + stats.r_e));

    let save_grads = vec![vec![0.0]; 3];
    let eps = 1e-5;
    for j in [0, tape.len() / 2, tape.len() - 1] {
        let mut gp = vec![0.0; 1];
        let mut sys = SdeSystemVjp {
            drift: drift(theta),
            diffusion,
            drift_vjp: |z: &[f64], _t: f64, w: &[f64], gz: &mut [f64], gth: &mut [f64]| {
                let c = (theta * z[0]).cos();
                gz[0] += w[0] * theta * c;
                gth[0] += w[0] * z[0] * c;
            },
            diffusion_vjp: |_z: &[f64], _t: f64, _w: &[f64], _gz: &mut [f64], _gp: &mut [f64]| {},
        };
        sde_backward_sys(
            &tape,
            &save_grads,
            &RegCoefs::global(0.0, 0.0).with_local(j, 1.0),
            &mut gp,
            &mut sys,
        );
        let term = |th: f64| sde_replay_errors(&tape, &[1.0], drift(th), diffusion)[j];
        let fd = (term(theta + eps) - term(theta - eps)) / (2.0 * eps);
        assert!(
            fd.abs() > 1e-12,
            "step {j}: term must depend on θ (fd={fd})"
        );
        assert!(
            rel_err(gp[0], fd) < 1e-4,
            "step {j}: SDE adjoint {} vs fd {fd}",
            gp[0]
        );
    }
}

#[test]
fn local_coefficient_stacks_on_top_of_global_r_e() {
    // RegCoefs::e_at semantics: local + global on the sampled step must
    // equal the sum of the two separate walks.
    let theta = 1.2f64;
    let ts = [0.0, 1.0];
    let opts = SolveOptions::new().with_tolerance(1e-6);
    let mut tape = OdeTape::new();
    let _ = solve_taped(f(theta), &[0.8], &ts, &opts, 100_000, &mut tape);
    assert!(tape.len() >= 2);
    let j = 1;
    let save_grads = vec![vec![0.0], vec![0.0]];

    let walk = |reg: RegCoefs| {
        let mut gp = vec![0.0; 1];
        let mut sys = OdeSystemVjp {
            drift: f(theta),
            vjp: f_vjp(theta),
        };
        ode_backward_sys(&tape, &opts.tableau, &save_grads, &reg, &mut gp, &mut sys);
        gp[0]
    };
    let combined = walk(RegCoefs::global(0.4, 0.0).with_local(j, 0.6));
    let global_only = walk(RegCoefs::global(0.4, 0.0));
    let local_only = walk(RegCoefs::global(0.0, 0.0).with_local(j, 0.6));
    // Linearity holds exactly in math; allow FP reordering noise only.
    let scale = combined.abs().max(global_only.abs() + local_only.abs());
    assert!(
        (combined - (global_only + local_only)).abs() <= 1e-9 * scale.max(1e-12),
        "combined {combined} vs split {global_only} + {local_only}"
    );
}
