//! Property suite pinning the vectorized batched kernels
//! (`models::kernels`) to their scalar references.
//!
//! Tolerance policy (DESIGN.md §Perf): the forward GEMM re-associates the
//! reduction (8-lane tree + serial tail) so it is compared to the seed
//! order under a 1e-12 relative tolerance; it is still *deterministic*
//! (two runs are bit-identical) and *batch-decomposition invariant* (a
//! batch of one reproduces the same row of a batch of 128 bit-for-bit).
//! The fused RK stage-combine and the scalar-fallback ablation path
//! preserve the reference FP sequence exactly, so those are pinned
//! bit-for-bit, not by tolerance.
//!
//! Everything runs inside ONE `#[test]` function: the
//! `kernels::set_scalar_fallback` knob is process-global, and parallel
//! test threads toggling it would race.

use regnde::models::kernels::{self, Act};
use regnde::models::Mlp;
use regnde::util::propcheck::{check, ensure, ensure_close, Gen};
use regnde::util::rng::Rng;

/// Random flat parameter vector in (-1, 1).
fn rand_theta(g: &mut Gen, mlp: &Mlp) -> Vec<f64> {
    g.vec_f64(mlp.n_params(), -1.0, 1.0)
}

/// Random MLP: 1–3 layers, dims 1–70, one of the three constructor
/// variants (plain / cubed input / tanh output).
fn rand_mlp(g: &mut Gen) -> Mlp {
    let n_layers = g.usize_in(1, 3);
    let dims: Vec<usize> = (0..=n_layers).map(|_| g.usize_in(1, 70)).collect();
    match g.usize_in(0, 2) {
        0 => Mlp::new(&dims),
        1 => Mlp::cubed(&dims),
        _ => Mlp::tanh_out(&dims),
    }
}

fn dense_act_matches_reference() {
    check("dense_act vs reference", 64, |g| {
        let rows = g.usize_in(1, 128);
        let in_dim = g.usize_in(1, 70);
        let out_dim = g.usize_in(1, 70);
        let act = if g.bool() { Act::Tanh } else { Act::Linear };
        let w = g.vec_f64(out_dim * in_dim, -2.0, 2.0);
        let bias = g.vec_f64(out_dim, -1.0, 1.0);
        let x = g.vec_f64(rows * in_dim, -2.0, 2.0);
        let mut out = vec![0.0; rows * out_dim];
        let mut out_ref = vec![0.0; rows * out_dim];
        kernels::dense_act(&w, &bias, &x, rows, in_dim, out_dim, act, &mut out);
        kernels::dense_act_ref(&w, &bias, &x, rows, in_dim, out_dim, act, &mut out_ref);
        for (k, (&a, &b)) in out.iter().zip(&out_ref).enumerate() {
            ensure_close(a, b, 1e-12, &format!("dense_act[{k}]"))?;
        }

        // Exact-order determinism: a second run is bit-identical.
        let mut out2 = vec![0.0; rows * out_dim];
        kernels::dense_act(&w, &bias, &x, rows, in_dim, out_dim, act, &mut out2);
        for (k, (&a, &b)) in out.iter().zip(&out2).enumerate() {
            ensure(
                a.to_bits() == b.to_bits(),
                format!("dense_act rerun differs at {k}: {a} vs {b}"),
            )?;
        }

        // Batch-decomposition invariance: any single row alone
        // reproduces its in-batch bits (serving-consistency contract).
        let r = g.usize_in(0, rows - 1);
        let mut row_out = vec![0.0; out_dim];
        kernels::dense_act(
            &w,
            &bias,
            &x[r * in_dim..(r + 1) * in_dim],
            1,
            in_dim,
            out_dim,
            act,
            &mut row_out,
        );
        for (k, (&a, &b)) in row_out.iter().zip(&out[r * out_dim..]).enumerate() {
            ensure(
                a.to_bits() == b.to_bits(),
                format!("row {r} out[{k}] batch-dependent: {a} vs {b}"),
            )?;
        }
        Ok(())
    });
}

fn forward_batch_matches_per_row() {
    check("forward_batch vs per-row forward", 64, |g| {
        let mlp = rand_mlp(g);
        let rows = g.usize_in(1, 16);
        let theta = rand_theta(g, &mlp);
        let (i, o) = (mlp.in_dim(), mlp.out_dim());
        let x = g.vec_f64(rows * i, -2.0, 2.0);

        let mut out = vec![0.0; rows * o];
        let mut scratch = mlp.batch_scratch(rows);
        mlp.forward_batch(&theta, &x, &mut out, &mut scratch);

        let mut row_out = vec![0.0; o];
        let mut sc = mlp.scratch();
        for r in 0..rows {
            mlp.forward(&theta, &x[r * i..(r + 1) * i], &mut row_out, &mut sc);
            for (k, (&a, &b)) in row_out.iter().zip(&out[r * o..]).enumerate() {
                ensure_close(a, b, 1e-12, &format!("forward_batch row {r} [{k}]"))?;
            }
        }

        // Determinism: re-running the batched pass is bit-identical.
        let mut out2 = vec![0.0; rows * o];
        mlp.forward_batch(&theta, &x, &mut out2, &mut scratch);
        for (k, (&a, &b)) in out.iter().zip(&out2).enumerate() {
            ensure(
                a.to_bits() == b.to_bits(),
                format!("forward_batch rerun differs at {k}"),
            )?;
        }
        Ok(())
    });
}

fn vjp_batch_matches_per_row() {
    check("vjp_batch vs per-row vjp", 64, |g| {
        let mlp = rand_mlp(g);
        let rows = g.usize_in(1, 16);
        let theta = rand_theta(g, &mlp);
        let (i, o) = (mlp.in_dim(), mlp.out_dim());
        let x = g.vec_f64(rows * i, -2.0, 2.0);
        let w = g.vec_f64(rows * o, -1.0, 1.0);

        let mut gx = vec![0.0; rows * i];
        let mut gt = vec![0.0; mlp.n_params()];
        let mut scratch = mlp.batch_scratch(rows);
        mlp.vjp_batch(&theta, &x, &w, &mut gx, &mut gt, &mut scratch);

        let mut gx_ref = vec![0.0; rows * i];
        let mut gt_ref = vec![0.0; mlp.n_params()];
        let mut sc = mlp.scratch();
        for r in 0..rows {
            mlp.vjp(
                &theta,
                &x[r * i..(r + 1) * i],
                &w[r * o..(r + 1) * o],
                &mut gx_ref[r * i..(r + 1) * i],
                &mut gt_ref,
                &mut sc,
            );
        }
        for (k, (&a, &b)) in gx.iter().zip(&gx_ref).enumerate() {
            ensure_close(a, b, 1e-10, &format!("vjp_batch gx[{k}]"))?;
        }
        for (k, (&a, &b)) in gt.iter().zip(&gt_ref).enumerate() {
            ensure_close(a, b, 1e-10, &format!("vjp_batch gtheta[{k}]"))?;
        }
        Ok(())
    });
}

/// Finite-difference gradcheck of the batched VJP (< 1e-4) on the loss
/// `Σ_r w_r · f(x_r)`.
fn fd_check_batch(mlp: &Mlp, rows: usize, seed: u64) {
    let mut g = Gen { rng: Rng::new(seed) };
    let theta = rand_theta(&mut g, mlp);
    let (i, o) = (mlp.in_dim(), mlp.out_dim());
    let x = g.vec_f64(rows * i, -1.0, 1.0);
    let w = g.vec_f64(rows * o, -1.0, 1.0);

    let mut gx = vec![0.0; rows * i];
    let mut gt = vec![0.0; mlp.n_params()];
    let mut scratch = mlp.batch_scratch(rows);
    mlp.vjp_batch(&theta, &x, &w, &mut gx, &mut gt, &mut scratch);

    let mut loss = |theta: &[f64], x: &[f64]| -> f64 {
        let mut out = vec![0.0; rows * o];
        mlp.forward_batch(theta, x, &mut out, &mut scratch);
        out.iter().zip(&w).map(|(o, w)| o * w).sum()
    };
    let eps = 1e-6;
    for k in 0..mlp.n_params() {
        let mut tp = theta.clone();
        tp[k] += eps;
        let mut tm = theta.clone();
        tm[k] -= eps;
        let fd = (loss(&tp, &x) - loss(&tm, &x)) / (2.0 * eps);
        assert!(
            (gt[k] - fd).abs() < 1e-4 * fd.abs().max(1.0),
            "param {k}: vjp_batch {} vs fd {fd}",
            gt[k]
        );
    }
    for k in 0..rows * i {
        let mut xp = x.clone();
        xp[k] += eps;
        let mut xm = x.clone();
        xm[k] -= eps;
        let fd = (loss(&theta, &xp) - loss(&theta, &xm)) / (2.0 * eps);
        assert!(
            (gx[k] - fd).abs() < 1e-4 * fd.abs().max(1.0),
            "input {k}: vjp_batch {} vs fd {fd}",
            gx[k]
        );
    }
}

fn rk_combine_is_bit_identical() {
    check("rk_combine vs reference (bitwise)", 64, |g| {
        let stages = g.usize_in(1, 9);
        let n = g.usize_in(1, 70);
        let ks = g.vec_f64(stages * n, -3.0, 3.0);
        let b = g.vec_f64(stages, -1.0, 1.0);
        let btilde = g.vec_f64(stages, -0.1, 0.1);
        let z = g.vec_f64(n, -2.0, 2.0);
        let h = g.f64_in(1e-4, 0.5);
        let mut znew = vec![0.0; n];
        let mut err = vec![0.0; n];
        let mut znew_ref = vec![0.0; n];
        let mut err_ref = vec![0.0; n];
        kernels::rk_combine(&ks, stages, n, &b, &btilde, &z, h, &mut znew, &mut err);
        kernels::rk_combine_ref(
            &ks,
            stages,
            n,
            &b,
            &btilde,
            &z,
            h,
            &mut znew_ref,
            &mut err_ref,
        );
        for d in 0..n {
            ensure(
                znew[d].to_bits() == znew_ref[d].to_bits(),
                format!("znew[{d}]: {} vs {}", znew[d], znew_ref[d]),
            )?;
            ensure(
                err[d].to_bits() == err_ref[d].to_bits(),
                format!("err[{d}]: {} vs {}", err[d], err_ref[d]),
            )?;
        }
        Ok(())
    });
}

/// The ablation knob must route the batched entry points onto the exact
/// scalar path (bit-identical to calling the per-row API directly).
fn scalar_fallback_routes_to_reference() {
    let mlp = Mlp::cubed(&[2, 16, 2]);
    let mut g = Gen { rng: Rng::new(0xAB1A) };
    let theta = rand_theta(&mut g, &mlp);
    let rows = 5;
    let x = g.vec_f64(rows * 2, -1.0, 1.0);
    let w = g.vec_f64(rows * 2, -1.0, 1.0);

    assert!(!kernels::scalar_fallback(), "knob must default off");
    kernels::set_scalar_fallback(true);
    assert!(kernels::scalar_fallback());

    let mut out = vec![0.0; rows * 2];
    let mut gx = vec![0.0; rows * 2];
    let mut gt = vec![0.0; mlp.n_params()];
    let mut scratch = mlp.batch_scratch(rows);
    mlp.forward_batch(&theta, &x, &mut out, &mut scratch);
    mlp.vjp_batch(&theta, &x, &w, &mut gx, &mut gt, &mut scratch);

    kernels::set_scalar_fallback(false);

    let mut sc = mlp.scratch();
    let mut row_out = vec![0.0; 2];
    let mut gx_ref = vec![0.0; rows * 2];
    let mut gt_ref = vec![0.0; mlp.n_params()];
    for r in 0..rows {
        mlp.forward(&theta, &x[r * 2..(r + 1) * 2], &mut row_out, &mut sc);
        for k in 0..2 {
            assert_eq!(
                row_out[k].to_bits(),
                out[r * 2 + k].to_bits(),
                "fallback forward must BE the scalar path"
            );
        }
        mlp.vjp(
            &theta,
            &x[r * 2..(r + 1) * 2],
            &w[r * 2..(r + 1) * 2],
            &mut gx_ref[r * 2..(r + 1) * 2],
            &mut gt_ref,
            &mut sc,
        );
    }
    for (a, b) in gx.iter().zip(&gx_ref) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    for (a, b) in gt.iter().zip(&gt_ref) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    // rk_combine under the knob is the reference two-pass loop.
    kernels::set_scalar_fallback(true);
    let (stages, n) = (7, 19);
    let mut g = Gen { rng: Rng::new(0xF0) };
    let ks = g.vec_f64(stages * n, -1.0, 1.0);
    let b = g.vec_f64(stages, -1.0, 1.0);
    let bt = g.vec_f64(stages, -0.1, 0.1);
    let z = g.vec_f64(n, -1.0, 1.0);
    let (mut zn, mut er) = (vec![0.0; n], vec![0.0; n]);
    let (mut zn_ref, mut er_ref) = (vec![0.0; n], vec![0.0; n]);
    kernels::rk_combine(&ks, stages, n, &b, &bt, &z, 0.125, &mut zn, &mut er);
    kernels::set_scalar_fallback(false);
    kernels::rk_combine_ref(&ks, stages, n, &b, &bt, &z, 0.125, &mut zn_ref, &mut er_ref);
    assert_eq!(zn, zn_ref);
    assert_eq!(er, er_ref);
}

/// One sequential test: the scalar-fallback knob is process-global, so
/// the sections must not run on parallel test threads.
#[test]
fn kernel_equivalence_suite() {
    dense_act_matches_reference();
    forward_batch_matches_per_row();
    vjp_batch_matches_per_row();
    fd_check_batch(&Mlp::new(&[3, 5, 2]), 4, 11);
    fd_check_batch(&Mlp::cubed(&[2, 6, 2]), 3, 12);
    fd_check_batch(&Mlp::tanh_out(&[4, 3]), 2, 13);
    fd_check_batch(&Mlp::new(&[2, 4]), 9, 14);
    rk_combine_is_bit_identical();
    scalar_fallback_routes_to_reference();
}
