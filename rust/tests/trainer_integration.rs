//! Integration tests over the full L3 trainer stack: backend + data +
//! budget routing + schedules, on real (tiny) training runs.
//!
//! These use very small epoch/iteration counts — they verify *plumbing and
//! semantics* (finite metrics, NFE accounting, router behaviour, method
//! coefficient wiring), not convergence; the benches cover the latter.
//!
//! Everything here runs on the native discrete-adjoint backend, so the
//! whole file executes in tier-1 CI with no artifacts or XLA.  The same
//! assertions against the PJRT engine live in the feature-gated `pjrt`
//! module at the bottom.

use regnde::coordinator::experiments::{self, TrainOpts};
use regnde::coordinator::Method;
use regnde::runtime::{Backend, NativeBackend};

fn backend() -> NativeBackend {
    NativeBackend::new()
}

fn tiny() -> TrainOpts {
    TrainOpts {
        epochs: 1,
        iters_per_epoch: 2,
        seed: 0,
        verbose: false,
    }
}

#[test]
fn spiral_node_vanilla_runs() {
    let be = backend();
    let r = experiments::run_by_name(&be, "spiral-node", Method::VANILLA, tiny()).unwrap();
    assert_eq!(r.epochs.len(), 1);
    assert!(r.epochs[0].loss.is_finite());
    assert!(r.predict_nfe > 0.0);
    assert!(r.train_time_s > 0.0);
}

#[test]
fn spiral_node_regularized_accumulates_r_terms() {
    let be = backend();
    let m = Method::parse("srnode+ernode").unwrap();
    let r = experiments::run_by_name(&be, "spiral-node", m, tiny()).unwrap();
    assert_eq!(r.method, "SRNODE + ERNODE");
    assert!(r.epochs[0].r_e > 0.0, "R_E accumulated");
    assert!(r.epochs[0].r_e2 > 0.0, "ΣE² variant surfaced in epoch records");
    assert!(r.epochs[0].r_s > 0.0, "R_S accumulated");
}

#[test]
fn spiral_node_regularization_changes_training() {
    // ERNODE's R_E gradient must actually steer the parameters: same
    // seed, different trajectory than vanilla after a few steps.
    let be = backend();
    let opts = TrainOpts {
        epochs: 1,
        iters_per_epoch: 5,
        seed: 0,
        verbose: false,
    };
    let v = experiments::run_by_name(&be, "spiral-node", Method::VANILLA, opts).unwrap();
    let e = experiments::run_by_name(
        &be,
        "spiral-node",
        Method::parse("ernode").unwrap(),
        opts,
    )
    .unwrap();
    assert_ne!(
        v.final_test_loss, e.final_test_loss,
        "regularizer gradient must alter the fit"
    );
}

#[test]
fn sr_method_combos_have_live_coef_s() {
    // The Method::parse combos that reach the native backend with a
    // nonzero coef_s must produce a *gradient* effect, not just a loss
    // offset: on the same seed, toggling the sr component off changes
    // the realized training trajectory.  `srnode+ernode` runs on
    // spiral-node (both regularizers in one objective); `steer+srnode`
    // runs on mnist-node, the experiment where STEER's per-iteration
    // end-time sampling is actually wired (its RNG stream is seeded per
    // run, so both sides draw identical t1 sequences and coef_s is the
    // only difference).
    let opts = TrainOpts {
        epochs: 1,
        iters_per_epoch: 4,
        seed: 0,
        verbose: false,
    };
    for (exp, with_sr, without_sr) in [
        ("spiral-node", "srnode+ernode", "ernode"),
        ("mnist-node", "steer+srnode", "steer"),
    ] {
        let be = backend();
        let sr =
            experiments::run_by_name(&be, exp, Method::parse(with_sr).unwrap(), opts)
                .unwrap();
        let base =
            experiments::run_by_name(&be, exp, Method::parse(without_sr).unwrap(), opts)
                .unwrap();
        assert!(sr.epochs[0].r_s > 0.0, "{with_sr}: R_S must accumulate");
        assert_ne!(
            sr.final_test_loss, base.final_test_loss,
            "{exp}: {with_sr} vs {without_sr}: coef_s must steer the \
             parameters (gradient path dead?)"
        );
    }
}

#[test]
fn mnist_node_methods_wire_coefficients() {
    let be = backend();
    let vanilla =
        experiments::run_by_name(&be, "mnist-node", Method::VANILLA, tiny()).unwrap();
    assert!(vanilla.epochs[0].loss.is_finite());
    assert!(vanilla.final_test_metric >= 0.0);
    let steer = experiments::run_by_name(
        &be,
        "mnist-node",
        Method::parse("steer").unwrap(),
        tiny(),
    )
    .unwrap();
    assert_eq!(steer.method, "STEER");
    assert!(steer.epochs[0].loss.is_finite());
}

#[test]
fn mnist_nsde_runs_and_counts_sde_nfe() {
    let be = backend();
    let r = experiments::run_by_name(
        &be,
        "mnist-nsde",
        Method::parse("ernsde").unwrap(),
        tiny(),
    )
    .unwrap();
    assert_eq!(r.method, "ERNSDE");
    // SDE accounting: 4 evals per attempt
    let rec = r.epochs[0];
    assert!((rec.nfe - 4.0 * (rec.naccept + rec.nreject)).abs() < 1e-6);
    assert!(rec.r_e > 0.0, "ERNSDE accumulates R_E");
}

#[test]
fn spiral_nsde_runs() {
    let be = backend();
    let r = experiments::run_by_name(
        &be,
        "spiral-nsde",
        Method::parse("srnsde").unwrap(),
        tiny(),
    )
    .unwrap();
    assert!(r.epochs[0].loss.is_finite());
    assert!(r.predict_nfe >= 29.0 * 4.0);
}

#[test]
fn latent_ode_runs_with_steer_grid_perturbation() {
    let be = backend();
    let r = experiments::run_by_name(
        &be,
        "latent-ode",
        Method::parse("steer").unwrap(),
        tiny(),
    )
    .unwrap();
    assert!(r.epochs[0].loss.is_finite());
    assert!(r.final_test_loss.is_finite());
}

#[test]
fn unknown_experiment_rejected() {
    let be = backend();
    assert!(experiments::run_by_name(&be, "cifar", Method::VANILLA, tiny()).is_err());
}

#[test]
fn replica_seeds_change_results() {
    let be = backend();
    let a = experiments::run_by_name(&be, "spiral-node", Method::VANILLA, tiny()).unwrap();
    let b = experiments::run_by_name(
        &be,
        "spiral-node",
        Method::VANILLA,
        TrainOpts { seed: 1, ..tiny() },
    )
    .unwrap();
    assert_ne!(a.epochs[0].loss, b.epochs[0].loss);
}

#[test]
fn same_seed_reproduces() {
    let be = backend();
    let a = experiments::run_by_name(&be, "spiral-node", Method::VANILLA, tiny()).unwrap();
    let b = experiments::run_by_name(&be, "spiral-node", Method::VANILLA, tiny()).unwrap();
    assert_eq!(a.epochs[0].loss, b.epochs[0].loss);
    assert_eq!(a.predict_nfe, b.predict_nfe);
}

#[test]
fn spiral_node_trains_under_dopri5() {
    // `--solver dopri5` end-to-end: the previously-unreachable tableau
    // threads through the backend's solve options into a real training
    // run (taped forward, discrete adjoint, Adam).
    let opts = TrainOpts {
        epochs: 1,
        iters_per_epoch: 5,
        seed: 0,
        verbose: false,
    };
    let be = NativeBackend::new().with_solver("dopri5").unwrap();
    let r = experiments::run_by_name(
        &be,
        "spiral-node",
        Method::parse("srnode+ernode").unwrap(),
        opts,
    )
    .unwrap();
    assert!(r.epochs[0].loss.is_finite());
    assert!(r.epochs[0].r_e > 0.0, "white-box stats flow under dopri5");
    assert!(r.epochs[0].r_s > 0.0, "dopri5 has a proper Shampine pair");
    assert!(r.predict_nfe > 0.0);

    // A different tableau is a genuinely different solve: NFE and the
    // realized fit diverge from the tsit5 default on the same seed.
    let tsit = experiments::run_by_name(
        &backend(),
        "spiral-node",
        Method::parse("srnode+ernode").unwrap(),
        opts,
    )
    .unwrap();
    assert!(
        (r.epochs[0].nfe, r.final_train_loss) != (tsit.epochs[0].nfe, tsit.final_train_loss),
        "dopri5 run must differ from tsit5"
    );

    // Case-insensitive at the CLI boundary; unknown names list the
    // registry instead of panicking.
    assert!(NativeBackend::new().with_solver("TSIT5").is_ok());
    let err = format!("{:#}", NativeBackend::new().with_solver("rk4").unwrap_err());
    assert!(err.contains("tsit5") && err.contains("dopri5") && err.contains("bs3"));
}

#[test]
fn lrnode_method_has_live_sampled_regularizer() {
    // The lrnode method grid entry: R_L accumulates, rides the epoch
    // records, and its gradient steers the parameters (same seed,
    // toggling lr off changes the trajectory).
    let opts = TrainOpts {
        epochs: 1,
        iters_per_epoch: 4,
        seed: 0,
        verbose: false,
    };
    let be = backend();
    let lr = experiments::run_by_name(&be, "spiral-node", Method::parse("lrnode").unwrap(), opts)
        .unwrap();
    assert_eq!(lr.method, "LRNODE");
    assert!(lr.epochs[0].r_l > 0.0, "sampled R_L must accumulate");
    let vanilla =
        experiments::run_by_name(&be, "spiral-node", Method::VANILLA, opts).unwrap();
    assert_eq!(vanilla.epochs[0].r_l, 0.0, "R_L reads 0 when lr is off");
    assert_ne!(
        lr.final_test_loss, vanilla.final_test_loss,
        "sampled-step gradient must alter the fit"
    );

    // SDE mirror: lrnsde on the spiral NSDE moment objective.
    let lrnsde = experiments::run_by_name(
        &be,
        "spiral-nsde",
        Method::parse("lrnsde").unwrap(),
        TrainOpts {
            epochs: 1,
            iters_per_epoch: 2,
            seed: 0,
            verbose: false,
        },
    )
    .unwrap();
    assert_eq!(lrnsde.method, "LRNSDE");
    assert!(lrnsde.epochs[0].r_l > 0.0, "ensemble R_L must accumulate");
    assert!(lrnsde.epochs[0].loss.is_finite());
}

#[test]
fn router_escalates_on_tiny_budgets_and_recovers() {
    // Force the first rungs to be unusable: the router must escalate to
    // the top rung, retry the batches there, and finish the run.
    let be = NativeBackend::new().with_ladder("spiral_node", vec![2, 4, 8192]);
    let r = experiments::run_by_name(&be, "spiral-node", Method::VANILLA, tiny()).unwrap();
    assert!(r.escalations >= 2, "tiny rungs must force escalation");
    assert!(r.epochs[0].loss.is_finite());
    assert_eq!(r.epochs[0].rung, 2, "run must settle on the top rung");
}

#[test]
fn native_backend_reports_model_info() {
    let be = backend();
    for model in ["spiral_node", "spiral_nsde", "mnist_node", "mnist_nsde", "latent_ode"] {
        let info = be.model(model).unwrap();
        assert!(info.params_size > 0);
        assert_eq!(info.opt_state_size, 2 * info.params_size);
        assert!(info.hyper.contains_key("lr"), "{model} must expose lr");
        let ladder = be.ladder(model, false).unwrap();
        assert!(ladder.windows(2).all(|w| w[0] < w[1]));
    }
}

/// The same plumbing assertions against the PJRT artifact engine.
/// Requires `--features pjrt`, real xla bindings and compiled artifacts.
#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use regnde::runtime::Engine;

    fn engine() -> Engine {
        Engine::new(regnde::default_artifacts_dir()).expect("artifacts built?")
    }

    #[test]
    fn spiral_node_vanilla_runs_on_engine() {
        let e = engine();
        let r = experiments::run_by_name(&e, "spiral-node", Method::VANILLA, tiny()).unwrap();
        assert!(r.epochs[0].loss.is_finite());
        assert!(r.predict_nfe > 0.0);
    }

    #[test]
    fn spiral_node_regularized_accumulates_r_terms_on_engine() {
        let e = engine();
        let m = Method::parse("srnode+ernode").unwrap();
        let r = experiments::run_by_name(&e, "spiral-node", m, tiny()).unwrap();
        assert!(r.epochs[0].r_e > 0.0);
        assert!(r.epochs[0].r_s > 0.0);
    }

    #[test]
    fn mnist_nsde_counts_sde_nfe_on_engine() {
        let e = engine();
        let r = experiments::run_by_name(
            &e,
            "mnist-nsde",
            Method::parse("ernsde").unwrap(),
            tiny(),
        )
        .unwrap();
        let rec = r.epochs[0];
        assert!((rec.nfe - 4.0 * (rec.naccept + rec.nreject)).abs() < 1e-6);
    }
}
