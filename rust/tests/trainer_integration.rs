//! Integration tests over the full L3 trainer stack: engine + data +
//! budget routing + schedules, on real (tiny) training runs.
//!
//! These use very small epoch/iteration counts — they verify *plumbing and
//! semantics* (finite metrics, NFE accounting, router behaviour, method
//! coefficient wiring), not convergence; the benches cover the latter.

use regnde::coordinator::experiments::{self, TrainOpts};
use regnde::coordinator::Method;
use regnde::runtime::Engine;

fn engine() -> Engine {
    Engine::new(regnde::default_artifacts_dir()).expect("artifacts built?")
}

fn tiny() -> TrainOpts {
    TrainOpts {
        epochs: 1,
        iters_per_epoch: 2,
        seed: 0,
        verbose: false,
    }
}

#[test]
fn spiral_node_vanilla_runs() {
    let e = engine();
    let r = experiments::run_by_name(&e, "spiral-node", Method::VANILLA, tiny()).unwrap();
    assert_eq!(r.epochs.len(), 1);
    assert!(r.epochs[0].loss.is_finite());
    assert!(r.predict_nfe > 0.0);
    assert!(r.train_time_s > 0.0);
}

#[test]
fn spiral_node_regularized_accumulates_r_terms() {
    let e = engine();
    let m = Method::parse("srnode+ernode").unwrap();
    let r = experiments::run_by_name(&e, "spiral-node", m, tiny()).unwrap();
    assert_eq!(r.method, "SRNODE + ERNODE");
    assert!(r.epochs[0].r_e > 0.0, "R_E accumulated");
    assert!(r.epochs[0].r_s > 0.0, "R_S accumulated");
}

#[test]
fn mnist_node_methods_wire_coefficients() {
    let e = engine();
    let vanilla =
        experiments::run_by_name(&e, "mnist-node", Method::VANILLA, tiny()).unwrap();
    assert!(vanilla.epochs[0].loss.is_finite());
    assert!(vanilla.final_test_metric >= 0.0);
    let steer = experiments::run_by_name(
        &e,
        "mnist-node",
        Method::parse("steer").unwrap(),
        tiny(),
    )
    .unwrap();
    assert_eq!(steer.method, "STEER");
    assert!(steer.epochs[0].loss.is_finite());
}

#[test]
fn mnist_nsde_runs_and_counts_sde_nfe() {
    let e = engine();
    let r = experiments::run_by_name(
        &e,
        "mnist-nsde",
        Method::parse("ernsde").unwrap(),
        tiny(),
    )
    .unwrap();
    assert_eq!(r.method, "ERNSDE");
    // SDE accounting: 4 evals per attempt
    let rec = r.epochs[0];
    assert!((rec.nfe - 4.0 * (rec.naccept + rec.nreject)).abs() < 1e-6);
}

#[test]
fn spiral_nsde_runs() {
    let e = engine();
    let r = experiments::run_by_name(
        &e,
        "spiral-nsde",
        Method::parse("srnsde").unwrap(),
        tiny(),
    )
    .unwrap();
    assert!(r.epochs[0].loss.is_finite());
    assert!(r.predict_nfe >= 29.0 * 4.0);
}

#[test]
fn latent_ode_runs_with_steer_grid_perturbation() {
    let e = engine();
    let r = experiments::run_by_name(
        &e,
        "latent-ode",
        Method::parse("steer").unwrap(),
        tiny(),
    )
    .unwrap();
    assert!(r.epochs[0].loss.is_finite());
    assert!(r.final_test_loss.is_finite());
}

#[test]
fn unknown_experiment_rejected() {
    let e = engine();
    assert!(experiments::run_by_name(&e, "cifar", Method::VANILLA, tiny()).is_err());
}

#[test]
fn replica_seeds_change_results() {
    let e = engine();
    let a = experiments::run_by_name(&e, "spiral-node", Method::VANILLA, tiny()).unwrap();
    let b = experiments::run_by_name(
        &e,
        "spiral-node",
        Method::VANILLA,
        TrainOpts {
            seed: 1,
            ..tiny()
        },
    )
    .unwrap();
    assert_ne!(a.epochs[0].loss, b.epochs[0].loss);
}

#[test]
fn same_seed_reproduces() {
    let e = engine();
    let a = experiments::run_by_name(&e, "spiral-node", Method::VANILLA, tiny()).unwrap();
    let b = experiments::run_by_name(&e, "spiral-node", Method::VANILLA, tiny()).unwrap();
    assert_eq!(a.epochs[0].loss, b.epochs[0].loss);
    assert_eq!(a.predict_nfe, b.predict_nfe);
}
