//! Distributed-vs-single-process bit-equality suite (ISSUE 9 acceptance).
//!
//! The subsystem's headline guarantee (DESIGN.md §Distributed): at equal
//! shard count, a distributed `train_step` — shards evaluated on remote
//! `regnde worker` processes over loopback TCP — produces **bit-identical**
//! parameters and metrics to single-process execution.  The chain is
//! (1) workers run the same native `grad_step` code on bit-exact wire
//! inputs (the f32/f64 frames are lossless), (2) the coordinator reduces
//! shard gradients in a fixed tree order with fixed f64 widening, and
//! (3) Adam consumes the reduced gradient identically.  This suite pins
//! all three links end-to-end, plus the checkpoint-resume continuation
//! (same run, interrupted and resumed, lands on the same bits).

use std::sync::Arc;

use regnde::coordinator::experiments::{self, ResumeState, TrainOpts};
use regnde::coordinator::Method;
use regnde::dist::{DistBackend, RemoteOpts, Worker, WorkerHandle, WorkerOpts};
use regnde::runtime::{Backend, NativeBackend, StepCoefs, TrainData, TrainState};
use regnde::util::rng::Rng;

const IMG_DIM: usize = 784;
const CLASSES: usize = 10;

fn spawn_worker() -> WorkerHandle {
    Worker::spawn(
        Arc::new(NativeBackend::new()),
        WorkerOpts {
            read_timeout: std::time::Duration::from_millis(20),
            ..Default::default()
        },
        "127.0.0.1:0",
    )
    .expect("spawn loopback worker")
}

/// Synthetic one-hot classification batch, `B` rows of `[IMG_DIM]`.
fn classify_batch(b: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0f32; b * IMG_DIM];
    rng.fill_normal(&mut x, 0.5);
    let mut y = vec![0.0f32; b * CLASSES];
    for row in 0..b {
        y[row * CLASSES + rng.below(CLASSES)] = 1.0;
    }
    (x, y)
}

fn assert_metrics_bits_equal(a: &regnde::runtime::Metrics, b: &regnde::runtime::Metrics) {
    for (name, x, y) in [
        ("loss", a.loss, b.loss),
        ("metric", a.metric, b.metric),
        ("nfe", a.nfe, b.nfe),
        ("naccept", a.naccept, b.naccept),
        ("nreject", a.nreject, b.nreject),
        ("r_e", a.r_e, b.r_e),
        ("r_e2", a.r_e2, b.r_e2),
        ("r_s", a.r_s, b.r_s),
        ("r_l", a.r_l, b.r_l),
        ("r_aux", a.r_aux, b.r_aux),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "metric {name} drifted: {x} vs {y}");
    }
    assert_eq!(a.success, b.success);
    assert_eq!(a.error, b.error);
}

fn assert_params_bits_equal(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: param {i} drifted: {x} vs {y}");
    }
}

/// Two loopback workers, two shards: every link of the chain at once.
/// Three sequential optimizer steps, each committed back into the train
/// state, so optimizer-state divergence would compound and surface.
#[test]
fn two_workers_two_shards_match_single_process_bitwise() {
    let w1 = spawn_worker();
    let w2 = spawn_worker();
    let workers = vec![w1.addr.to_string(), w2.addr.to_string()];

    let remote = DistBackend::remote(NativeBackend::new(), &workers, Some(2), RemoteOpts::default())
        .expect("remote backend");
    let local = DistBackend::local(NativeBackend::new(), 2);

    let model = "mnist_node";
    let info = local.model(model).expect("model info");
    let params = local.init_params(model, 11).expect("init");
    let (x, y) = classify_batch(8, 0xD157);
    let data = TrainData::Classify { x: &x, y: &y };

    let mut sr = TrainState {
        params: params.clone(),
        opt_state: vec![0.0; info.opt_state_size],
        iter: 0,
    };
    let mut sl = sr.clone();
    for step in 0..3 {
        let coefs = StepCoefs {
            lr: 0.05,
            seed: 1000 + step,
            ..Default::default()
        };
        let mr = remote
            .train_step(model, false, 0, &sr, &data, &coefs)
            .expect("remote step");
        let ml = local
            .train_step(model, false, 0, &sl, &data, &coefs)
            .expect("local step");
        assert_metrics_bits_equal(&mr.metrics, &ml.metrics);
        assert_params_bits_equal(&mr.params, &ml.params, "params");
        assert_params_bits_equal(&mr.opt_state, &ml.opt_state, "opt_state");
        sr.update(mr.params, mr.opt_state).expect("commit remote step");
        sl.update(ml.params, ml.opt_state).expect("commit local step");
        assert_eq!(sr.iter, sl.iter);
    }

    w1.kill();
    w2.kill();
}

/// Observability must not perturb the bit-equality guarantee: with span
/// collection enabled and the metrics registry live (both are process
/// globals a serving or training host would have on), the remote-vs-local
/// comparison still lands on identical bits, and the dist counters move.
#[test]
fn remote_step_is_bit_identical_with_observability_enabled() {
    use regnde::obs::metrics;

    regnde::obs::span::enable(4096);
    let bytes = metrics::registry().counter("regnde_dist_bytes_sent_total");
    let before = bytes.get();

    let w1 = spawn_worker();
    let workers = vec![w1.addr.to_string()];
    let remote = DistBackend::remote(NativeBackend::new(), &workers, Some(2), RemoteOpts::default())
        .expect("remote backend");
    let local = DistBackend::local(NativeBackend::new(), 2);

    let model = "mnist_node";
    let info = local.model(model).expect("model info");
    let params = local.init_params(model, 17).expect("init");
    let (x, y) = classify_batch(6, 0xB0B5);
    let data = TrainData::Classify { x: &x, y: &y };
    let state = TrainState {
        params,
        opt_state: vec![0.0; info.opt_state_size],
        iter: 0,
    };
    let coefs = StepCoefs {
        lr: 0.05,
        seed: 77,
        ..Default::default()
    };

    let mr = remote
        .train_step(model, false, 0, &state, &data, &coefs)
        .expect("remote step");
    let ml = local
        .train_step(model, false, 0, &state, &data, &coefs)
        .expect("local step");
    assert_metrics_bits_equal(&mr.metrics, &ml.metrics);
    assert_params_bits_equal(&mr.params, &ml.params, "obs params");
    assert_params_bits_equal(&mr.opt_state, &ml.opt_state, "obs opt_state");

    // The taps themselves fired: bytes went over the loopback wire.
    assert!(
        bytes.get() > before,
        "regnde_dist_bytes_sent_total must count the remote step's frames"
    );

    w1.kill();
}

/// A full experiment epoch through the coordinator's budget router on
/// the distributed backend vs the single-process sharded backend — the
/// exact comparison the CI smoke job greps for via checkpoints.
#[test]
fn full_experiment_run_matches_single_process_bitwise() {
    let w1 = spawn_worker();
    let w2 = spawn_worker();
    let workers = vec![w1.addr.to_string(), w2.addr.to_string()];

    let opts = TrainOpts {
        epochs: 1,
        iters_per_epoch: 2,
        seed: 5,
        verbose: false,
    };
    let method = Method::VANILLA;

    let remote = DistBackend::remote(NativeBackend::new(), &workers, Some(2), RemoteOpts::default())
        .expect("remote backend");
    let reference = DistBackend::local(NativeBackend::new(), 2);

    let rr = experiments::run_by_name(&remote, "mnist-node", method, opts).expect("remote run");
    let rl = experiments::run_by_name(&reference, "mnist-node", method, opts).expect("local run");

    assert_params_bits_equal(&rr.final_params, &rl.final_params, "final params");
    assert_params_bits_equal(&rr.final_opt_state, &rl.final_opt_state, "final opt state");
    assert_eq!(rr.final_iter, rl.final_iter);
    assert_eq!(rr.final_rung, rl.final_rung);
    assert_eq!(
        rr.final_test_loss.to_bits(),
        rl.final_test_loss.to_bits(),
        "final test loss drifted"
    );

    w1.kill();
    w2.kill();
}

/// Unsplittable data (a single ground-truth trajectory) with more
/// shards than items: the empty shards are skipped and the result stays
/// bit-identical to the plain native backend.
#[test]
fn remote_unsplittable_data_matches_plain_native() {
    let w1 = spawn_worker();
    let workers = vec![w1.addr.to_string()];

    let remote = DistBackend::remote(NativeBackend::new(), &workers, Some(3), RemoteOpts::default())
        .expect("remote backend");
    let plain = NativeBackend::new();

    let opts = TrainOpts {
        epochs: 1,
        iters_per_epoch: 3,
        seed: 2,
        verbose: false,
    };
    let rr = experiments::run_by_name(&remote, "spiral-node", Method::VANILLA, opts)
        .expect("remote run");
    let rp = experiments::run_by_name(&plain, "spiral-node", Method::VANILLA, opts)
        .expect("plain run");
    assert_params_bits_equal(&rr.final_params, &rp.final_params, "final params");
    assert_eq!(rr.final_test_loss.to_bits(), rp.final_test_loss.to_bits());

    w1.kill();
}

/// Checkpoint-resume continuation (satellite: checkpoint schema v2):
/// train E epochs straight vs train 1, hand the RunResult's training
/// position to a resumed run for E-1 more — same final bits.  Covers
/// the Adam moments, the iteration counter, the ladder rung + descent
/// window, and the RNG/batcher fast-forward in the drivers.
#[test]
fn resume_continues_bit_identically() {
    for (exp, seed) in [("spiral-node", 3u64), ("mnist-node", 4u64)] {
        let backend = NativeBackend::new();
        let full_opts = TrainOpts {
            epochs: 2,
            iters_per_epoch: 3,
            seed,
            verbose: false,
        };
        let head_opts = TrainOpts { epochs: 1, ..full_opts };

        let full = experiments::run_by_name(&backend, exp, Method::VANILLA, full_opts)
            .expect("uninterrupted run");
        let head = experiments::run_by_name(&backend, exp, Method::VANILLA, head_opts)
            .expect("first-epoch run");
        let resume = ResumeState {
            params: head.final_params.clone(),
            opt_state: head.final_opt_state.clone(),
            iter: head.final_iter,
            rung: head.final_rung,
            window: head.final_window.clone(),
            epochs_done: head.epochs_done,
            // What the head run's checkpoint records: its own target.
            total_epochs: head_opts.epochs,
        };
        let tail = experiments::run_by_name_resumed(
            &backend,
            exp,
            Method::VANILLA,
            head_opts,
            Some(&resume),
        )
        .expect("resumed run");

        assert_eq!(tail.epochs_done, full.epochs_done, "{exp}: epoch accounting");
        assert_params_bits_equal(&tail.final_params, &full.final_params, exp);
        assert_params_bits_equal(&tail.final_opt_state, &full.final_opt_state, exp);
        assert_eq!(tail.final_iter, full.final_iter, "{exp}: iter");
        assert_eq!(tail.final_rung, full.final_rung, "{exp}: rung");
    }
}

/// ER's `ExpAnneal` spans the *whole* run, so an interrupted run only
/// continues bit-identically if every segment anneals over the same
/// epoch target — the `ResumeState::total_epochs` / checkpoint
/// `train.total_epochs` record.  The head segment here runs 2 of a
/// declared 3-epoch target, then the tail finishes it; both must land
/// on the uninterrupted 3-epoch run's exact bits.
#[test]
fn er_anneal_resume_reuses_recorded_epoch_target() {
    let backend = NativeBackend::new();
    let method = Method {
        er: true,
        ..Method::VANILLA
    };
    let full_opts = TrainOpts {
        epochs: 3,
        iters_per_epoch: 2,
        seed: 6,
        verbose: false,
    };
    let head_opts = TrainOpts { epochs: 2, ..full_opts };
    let tail_opts = TrainOpts { epochs: 1, ..full_opts };

    let full = experiments::run_by_name(&backend, "mnist-node", method, full_opts)
        .expect("uninterrupted run");

    // Head segment: fresh state, but annealing over the declared
    // 3-epoch target (what a planned interruption records up front).
    let declared = ResumeState {
        params: backend
            .init_params("mnist_node", full_opts.seed as u32)
            .expect("init"),
        opt_state: Vec::new(),
        iter: 0,
        rung: 0,
        window: Vec::new(),
        epochs_done: 0,
        total_epochs: full_opts.epochs,
    };
    let head = experiments::run_by_name_resumed(
        &backend,
        "mnist-node",
        method,
        head_opts,
        Some(&declared),
    )
    .expect("head segment");

    let resume = ResumeState {
        params: head.final_params.clone(),
        opt_state: head.final_opt_state.clone(),
        iter: head.final_iter,
        rung: head.final_rung,
        window: head.final_window.clone(),
        epochs_done: head.epochs_done,
        total_epochs: full_opts.epochs,
    };
    let tail = experiments::run_by_name_resumed(
        &backend,
        "mnist-node",
        method,
        tail_opts,
        Some(&resume),
    )
    .expect("tail segment");

    assert_eq!(tail.epochs_done, full.epochs_done, "epoch accounting");
    assert_params_bits_equal(&tail.final_params, &full.final_params, "er params");
    assert_params_bits_equal(&tail.final_opt_state, &full.final_opt_state, "er opt_state");
    assert_eq!(tail.final_iter, full.final_iter, "iter");
    assert_eq!(tail.final_rung, full.final_rung, "rung");
}
