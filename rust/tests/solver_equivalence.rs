//! Refactor-equivalence suite: the allocation-free flat-arena stepper
//! behind the **unified `solve()` API** must reproduce the seed solver's
//! semantics *exactly*.
//!
//! `seed_reference` below is a faithful transcription of the pre-refactor
//! stepper (per-step `Vec<Vec<f64>>` stages, per-attempt scratch allocs,
//! cloned tableau, its own options bundle) — the behavioral contract the
//! rewrite must preserve.  The current side is exercised through
//! [`regnde::solvers::solve`] / `ode::drive` — the closure-based legacy
//! shims this suite used to pin are retired, so the unified entry point
//! *is* the seed-semantics surface now.  Every accepted/rejected step
//! takes the same branch with the same floats, so the counters must be
//! identical and states must agree to <= 1e-12 (they are in fact
//! bit-identical; the tolerance guards against platform FMA differences
//! only).
//!
//! The fused stage-combine (`models::kernels::rk_combine`) keeps this
//! pin intact *by construction*: it chunks dims 8 wide with the stage
//! loop innermost, so every dim still accumulates stage terms in tableau
//! order — the exact FP sequence of the seed's two-pass loop.  Only the
//! *network* forward GEMM re-associates its reduction, and that lives
//! outside this suite's closures; its tolerance contract is pinned by
//! `tests/kernel_equivalence.rs` (accumulation-order policy in
//! DESIGN.md §Perf).

use regnde::solvers::ode::Stats;
use regnde::solvers::problems;
use regnde::solvers::tableau::Tableau;
use regnde::solvers::{
    solve_ensemble, EnsembleOptions, OdeSystem, Saveat, SolveOptions, SolveOutcome, StepBudget,
    Taping,
};
use regnde::util::propcheck;

/// The seed (pre-refactor) stepper, kept verbatim as the reference — its
/// `SeedOptions` mirror the seed's `OdeOptions` bundle (per-segment
/// `max_steps` semantics).
mod seed_reference {
    use regnde::solvers::ode::Stats;
    use regnde::solvers::tableau::Tableau;

    const SAFETY: f64 = 0.9;
    const MIN_FACTOR: f64 = 0.2;
    const MAX_FACTOR: f64 = 10.0;
    const PI_BETA: f64 = 0.04;
    const EPS: f64 = 1e-12;

    /// The seed's options bundle (what `OdeOptions` was before the
    /// unified API).
    #[derive(Clone, Debug)]
    pub struct SeedOptions {
        pub tableau: Tableau,
        pub rtol: f64,
        pub atol: f64,
        pub max_steps: u64,
        pub dt0: Option<f64>,
    }

    impl Default for SeedOptions {
        fn default() -> Self {
            Self {
                tableau: Tableau::tsit5(),
                rtol: 1e-6,
                atol: 1e-6,
                max_steps: 100_000,
                dt0: None,
            }
        }
    }

    fn rms(v: &[f64]) -> f64 {
        (v.iter().map(|x| x * x).sum::<f64>() / v.len() as f64 + 1e-300).sqrt()
    }

    fn error_ratio(e: &[f64], z0: &[f64], z1: &[f64], rtol: f64, atol: f64) -> f64 {
        let mut acc = 0.0;
        for i in 0..e.len() {
            let scale = atol + z0[i].abs().max(z1[i].abs()) * rtol;
            let r = e[i] / scale;
            acc += r * r;
        }
        (acc / e.len() as f64 + 1e-300).sqrt()
    }

    fn pi_factor(q: f64, q_prev: f64, order: usize) -> f64 {
        let alpha = 1.0 / order as f64 - 0.75 * PI_BETA;
        let f = SAFETY * q.max(1e-10).powf(-alpha) * q_prev.max(1e-10).powf(PI_BETA);
        f.clamp(MIN_FACTOR, MAX_FACTOR)
    }

    fn reject_factor(q: f64, order: usize) -> f64 {
        let alpha = 1.0 / order as f64;
        (SAFETY * q.max(1e-10).powf(-alpha)).clamp(MIN_FACTOR, 1.0)
    }

    struct Stepper<'a, F: FnMut(&[f64], f64, &mut [f64])> {
        f: F,
        tab: &'a Tableau,
        opts: &'a SeedOptions,
        k1: Vec<f64>,
        h: f64,
        q_prev: f64,
        stats: Stats,
        ks: Vec<Vec<f64>>,
        zi: Vec<f64>,
        znew: Vec<f64>,
        err: Vec<f64>,
    }

    impl<'a, F: FnMut(&[f64], f64, &mut [f64])> Stepper<'a, F> {
        fn new(
            mut f: F,
            tab: &'a Tableau,
            opts: &'a SeedOptions,
            z0: &[f64],
            t0: f64,
            span: f64,
        ) -> Self {
            let n = z0.len();
            let mut k1 = vec![0.0; n];
            f(z0, t0, &mut k1);
            let h0 = opts.dt0.unwrap_or_else(|| 0.01 * span / rms(&k1).max(1.0));
            Self {
                f,
                tab,
                opts,
                k1,
                h: h0,
                q_prev: 1.0,
                stats: Stats {
                    nfe: 1,
                    ..Default::default()
                },
                ks: vec![vec![0.0; n]; tab.stages()],
                zi: vec![0.0; n],
                znew: vec![0.0; n],
                err: vec![0.0; n],
            }
        }

        fn advance(&mut self, z: &mut Vec<f64>, t: &mut f64, t1: f64, budget: u64) -> bool {
            let s = self.tab.stages();
            let n = z.len();
            let mut attempts = 0;
            while *t < t1 - 1e-12 * t1.abs().max(1.0) {
                if attempts >= budget {
                    return false;
                }
                attempts += 1;
                let h = self.h.min(t1 - *t).max(EPS);

                self.ks[0].copy_from_slice(&self.k1);
                let (sx, sy) = self.tab.stiff_pair;
                let mut g_x = vec![0.0; if sx == 0 { n } else { 0 }];
                if sx == 0 {
                    g_x.copy_from_slice(z);
                }
                let mut g_y = vec![0.0; n];
                for i in 1..s {
                    self.zi.copy_from_slice(z);
                    for (j, &aij) in self.tab.a[i].iter().enumerate() {
                        if aij != 0.0 {
                            for d in 0..n {
                                self.zi[d] += h * aij * self.ks[j][d];
                            }
                        }
                    }
                    if i == sx {
                        g_x = self.zi.clone();
                    }
                    if i == sy {
                        g_y.copy_from_slice(&self.zi);
                    }
                    let ti = *t + self.tab.c[i] * h;
                    let (before, after) = self.ks.split_at_mut(i);
                    let _ = before;
                    (self.f)(&self.zi, ti, &mut after[0]);
                }
                self.stats.nfe += self.tab.nfe_per_attempt() as u64;

                for d in 0..n {
                    let mut acc_b = 0.0;
                    let mut acc_bt = 0.0;
                    for i in 0..s {
                        acc_b += self.tab.b[i] * self.ks[i][d];
                        acc_bt += self.tab.btilde[i] * self.ks[i][d];
                    }
                    self.znew[d] = z[d] + h * acc_b;
                    self.err[d] = h * acc_bt;
                }

                let q = error_ratio(&self.err, z, &self.znew, self.opts.rtol, self.opts.atol);
                let e_norm = rms(&self.err);

                if q <= 1.0 {
                    let mut dnum = vec![0.0; n];
                    let mut dden = vec![0.0; n];
                    for d in 0..n {
                        dnum[d] = self.ks[sy][d] - self.ks[sx][d];
                        dden[d] = g_y[d] - g_x[d];
                    }
                    let stiff = rms(&dnum) / (rms(&dden) + EPS);

                    self.stats.r_e += e_norm * h.abs();
                    self.stats.r_e2 += e_norm * e_norm;
                    self.stats.r_s += stiff;
                    self.stats.naccept += 1;
                    *t += h;
                    std::mem::swap(z, &mut self.znew);
                    self.k1.copy_from_slice(&self.ks[s - 1]);
                    self.h = h * pi_factor(q, self.q_prev, self.tab.order);
                    self.q_prev = q.max(1e-4);
                } else {
                    self.stats.nreject += 1;
                    self.h = h * reject_factor(q, self.tab.order);
                }
            }
            true
        }
    }

    pub fn solve<F: FnMut(&[f64], f64, &mut [f64])>(
        f: F,
        z0: &[f64],
        t0: f64,
        t1: f64,
        opts: &SeedOptions,
    ) -> (Vec<f64>, Stats, bool) {
        let tab = opts.tableau.clone();
        let mut stepper = Stepper::new(f, &tab, opts, z0, t0, t1 - t0);
        let mut z = z0.to_vec();
        let mut t = t0;
        let ok = stepper.advance(&mut z, &mut t, t1, opts.max_steps);
        (z, stepper.stats, ok)
    }

    pub fn solve_saveat<F: FnMut(&[f64], f64, &mut [f64])>(
        f: F,
        z0: &[f64],
        ts: &[f64],
        opts: &SeedOptions,
    ) -> (Vec<Vec<f64>>, Stats, bool) {
        let tab = opts.tableau.clone();
        let mut stepper = Stepper::new(f, &tab, opts, z0, ts[0], ts[ts.len() - 1] - ts[0]);
        let mut z = z0.to_vec();
        let mut t = ts[0];
        let mut out = Vec::with_capacity(ts.len());
        out.push(z.clone());
        let mut ok = true;
        for &t_hi in &ts[1..] {
            ok &= stepper.advance(&mut z, &mut t, t_hi, opts.max_steps);
            out.push(z.clone());
        }
        (out, stepper.stats, ok)
    }
}

use seed_reference::SeedOptions;

/// The unified-API equivalent of a [`SeedOptions`]: same tableau,
/// tolerances and per-segment budget semantics.
fn unified(opts: &SeedOptions) -> SolveOptions {
    let mut u = SolveOptions::new()
        .with_tableau(opts.tableau.clone())
        .with_tolerances(opts.rtol, opts.atol)
        .with_budget(StepBudget::PerSegment(opts.max_steps));
    if let Some(dt0) = opts.dt0 {
        u = u.with_dt0(dt0);
    }
    u
}

/// Current side of the comparison: one span solve through the unified
/// entry point.
fn unified_solve(
    f: impl FnMut(&[f64], f64, &mut [f64]),
    z0: &[f64],
    t0: f64,
    t1: f64,
    opts: &SeedOptions,
) -> SolveOutcome {
    let mut sys = OdeSystem(f);
    regnde::solvers::solve(
        &mut sys,
        z0,
        Saveat::Span { t0, t1 },
        &unified(opts),
        None,
        Taping::Off,
        &mut [],
    )
    .1
    .expect("equivalence solve failed")
}

fn assert_stats_equal(new: &Stats, old: &Stats, what: &str) {
    assert_eq!(new.nfe, old.nfe, "{what}: nfe");
    assert_eq!(new.naccept, old.naccept, "{what}: naccept");
    assert_eq!(new.nreject, old.nreject, "{what}: nreject");
    assert!(
        (new.r_e - old.r_e).abs() <= 1e-12 * (1.0 + old.r_e.abs()),
        "{what}: r_e {} vs {}",
        new.r_e,
        old.r_e
    );
    assert!(
        (new.r_s - old.r_s).abs() <= 1e-12 * (1.0 + old.r_s.abs()),
        "{what}: r_s {} vs {}",
        new.r_s,
        old.r_s
    );
}

fn check_solve_case(
    name: &str,
    f: impl Fn(&[f64], f64, &mut [f64]) + Copy,
    z0: &[f64],
    t1: f64,
    tableau: Tableau,
    tol: f64,
) {
    let opts = SeedOptions {
        tableau,
        rtol: tol,
        atol: tol,
        max_steps: 2_000_000,
        ..Default::default()
    };
    let new = unified_solve(f, z0, 0.0, t1, &opts);
    let (z_old, stats_old, ok_old) = seed_reference::solve(f, z0, 0.0, t1, &opts);
    assert!(ok_old, "{name}: seed reference solve failed");
    assert_stats_equal(&new.stats, &stats_old, name);
    for d in 0..z0.len() {
        assert!(
            (new.z[d] - z_old[d]).abs() <= 1e-12 * (1.0 + z_old[d].abs()),
            "{name} dim {d}: {} vs {}",
            new.z[d],
            z_old[d]
        );
    }
}

#[test]
fn spiral_matches_seed_semantics() {
    for tol in [1e-4, 1e-6, 1e-8] {
        check_solve_case(
            "spiral/tsit5",
            problems::spiral_ode,
            &[2.0, 0.0],
            1.5,
            Tableau::tsit5(),
            tol,
        );
        check_solve_case(
            "spiral/dopri5",
            problems::spiral_ode,
            &[2.0, 0.0],
            1.5,
            Tableau::dopri5(),
            tol,
        );
    }
}

#[test]
fn van_der_pol_matches_seed_semantics() {
    // Moderately stiff: exercises the reject branch and the Shampine pair.
    let f = |z: &[f64], _t: f64, dz: &mut [f64]| {
        let mu = 5.0;
        dz[0] = z[1];
        dz[1] = mu * ((1.0 - z[0] * z[0]) * z[1]) - z[0];
    };
    for tol in [1e-5, 1e-7] {
        check_solve_case("vdp/tsit5", f, &[2.0, 0.0], 5.0, Tableau::tsit5(), tol);
    }
    // bs3 exercises the sx == 0 stiffness-pair path.
    check_solve_case("vdp/bs3", f, &[2.0, 0.0], 5.0, Tableau::bs3(), 1e-5);
}

#[test]
fn exp_decay_matches_seed_semantics() {
    let f = |z: &[f64], _t: f64, dz: &mut [f64]| {
        for i in 0..z.len() {
            dz[i] = -z[i];
        }
    };
    for tol in [1e-3, 1e-6, 1e-9] {
        check_solve_case("exp/tsit5", f, &[1.0, 2.0, -0.5], 1.0, Tableau::tsit5(), tol);
    }
}

#[test]
fn saveat_matches_seed_semantics() {
    let ts: Vec<f64> = (0..30).map(|i| 1.5 * i as f64 / 29.0).collect();
    let opts = SeedOptions {
        rtol: 1e-6,
        atol: 1e-6,
        ..Default::default()
    };
    let mut sys = OdeSystem(problems::spiral_ode);
    let (zs_new, out) = regnde::solvers::solve(
        &mut sys,
        &[2.0, 0.0],
        Saveat::Grid(&ts),
        &unified(&opts),
        None,
        Taping::Off,
        &mut [],
    );
    let (zs_old, stats_old, ok_old) =
        seed_reference::solve_saveat(problems::spiral_ode, &[2.0, 0.0], &ts, &opts);
    let out = out.expect("saveat solve failed");
    assert!(ok_old);
    assert_stats_equal(&out.stats, &stats_old, "saveat");
    for (k, (a, b)) in zs_new.iter().zip(&zs_old).enumerate() {
        for d in 0..2 {
            assert!(
                (a[d] - b[d]).abs() <= 1e-12 * (1.0 + b[d].abs()),
                "saveat point {k} dim {d}: {} vs {}",
                a[d],
                b[d]
            );
        }
    }
}

#[test]
fn trace_recorder_is_bit_transparent() {
    // Observers only *read* the per-step view (DESIGN.md §Observability):
    // attaching a TraceRecorder must leave every float and counter of the
    // solve bit-identical to the bare run, while capturing one entry per
    // accepted step with monotone time and cumulative counters.
    use regnde::obs::trace::TraceRecorder;
    for tol in [1e-4, 1e-7] {
        let opts = SolveOptions::new().with_tolerance(tol);
        let mut sys = OdeSystem(problems::spiral_ode);
        let bare = regnde::solvers::solve(
            &mut sys,
            &[2.0, 0.0],
            Saveat::Span { t0: 0.0, t1: 1.5 },
            &opts,
            None,
            Taping::Off,
            &mut [],
        )
        .1
        .expect("bare solve failed");

        let mut rec = TraceRecorder::with_capacity(1 << 14);
        let mut sys = OdeSystem(problems::spiral_ode);
        let traced = regnde::solvers::solve(
            &mut sys,
            &[2.0, 0.0],
            Saveat::Span { t0: 0.0, t1: 1.5 },
            &opts,
            None,
            Taping::Off,
            &mut [&mut rec],
        )
        .1
        .expect("traced solve failed");

        assert_eq!(traced.z, bare.z, "tol {tol}: states must be bit-identical");
        assert_eq!(traced.stats.nfe, bare.stats.nfe, "tol {tol}: nfe");
        assert_eq!(traced.stats.naccept, bare.stats.naccept, "tol {tol}: naccept");
        assert_eq!(traced.stats.nreject, bare.stats.nreject, "tol {tol}: nreject");
        assert!(
            traced.stats.r_e == bare.stats.r_e && traced.stats.r_s == bare.stats.r_s,
            "tol {tol}: regularization integrals must be bit-identical"
        );

        assert_eq!(rec.dropped(), 0, "tol {tol}: capacity must cover the solve");
        assert_eq!(
            rec.steps().len() as u64,
            traced.stats.naccept,
            "tol {tol}: one trace entry per accepted step"
        );
        let mut prev_t = f64::NEG_INFINITY;
        let mut prev_nfe = 0;
        for (k, s) in rec.steps().iter().enumerate() {
            assert_eq!(s.index, k as u64, "tol {tol}: step ordinals are dense");
            assert!(s.t > prev_t, "tol {tol}: step times must be monotone");
            assert!(s.h > 0.0 && s.error.is_finite() && s.stiffness.is_finite());
            assert!(s.nfe > prev_nfe, "tol {tol}: cumulative nfe must grow");
            prev_t = s.t;
            prev_nfe = s.nfe;
        }
        let last = rec.steps().last().expect("non-empty trace");
        assert_eq!(last.nfe, traced.stats.nfe, "tol {tol}: final cumulative nfe");
        assert_eq!(
            last.nreject, traced.stats.nreject,
            "tol {tol}: final cumulative nreject"
        );
    }
}

#[test]
fn prop_ensemble_of_copies_matches_independent_solves() {
    propcheck::check("ensemble == N independent solves", 25, |g| {
        let dim = g.usize_in(1, 4);
        let n_copies = g.usize_in(2, 12);
        let z0: Vec<f64> = g.vec_f64(dim, -2.0, 2.0);
        let lambda = g.f64_in(0.2, 3.0);
        let t1 = g.f64_in(0.4, 2.0);
        let f = move |z: &[f64], _t: f64, dz: &mut [f64]| {
            for i in 0..z.len() {
                dz[i] = -lambda * z[i] + 0.1 * z[i] * z[i] * z[i].sin();
            }
        };
        let opts = SolveOptions::new().with_tolerance(1e-6);
        let z0s: Vec<Vec<f64>> = (0..n_copies).map(|_| z0.clone()).collect();
        let eopts = EnsembleOptions {
            workers: g.usize_in(1, 4),
            chunk: g.usize_in(1, 5),
        };
        let ensemble = solve_ensemble(&f, &z0s, 0.0, t1, &opts, &eopts);
        let mut sys = OdeSystem(f);
        let (_, solo) = regnde::solvers::solve(
            &mut sys,
            &z0,
            Saveat::Span { t0: 0.0, t1 },
            &opts,
            None,
            Taping::Off,
            &mut [],
        );
        let solo = solo.expect("independent solve failed");
        for (i, out) in ensemble.iter().enumerate() {
            let out = out.as_ref().expect("ensemble member failed");
            propcheck::ensure(
                out.z == solo.z
                    && out.stats.nfe == solo.stats.nfe
                    && out.stats.naccept == solo.stats.naccept
                    && out.stats.nreject == solo.stats.nreject,
                format!("copy {i} diverged from independent solve"),
            )?;
        }
        Ok(())
    });
}
