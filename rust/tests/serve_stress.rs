//! Deterministic serving stress test (ISSUE 8): hammer the batcher's
//! window-close path against the server's drain shutdown.
//!
//! K seeded client lanes flood predict requests through tiny batch
//! windows while one lane fires `shutdown` mid-flood.  The invariants:
//!
//! * every request a lane manages to send gets **exactly one** terminal
//!   reply (`traj`/`error`/`shed`) — never a second line, never a hang,
//! * after the drain the accept loop exits and the serve thread joins
//!   within a bounded number of poll ticks,
//! * replies that arrive are well-formed (trajectories have the serving
//!   grid length; errors carry a parseable kind).
//!
//! All "randomness" is a per-lane LCG seeded by the lane index, so a
//! failure replays exactly.  The nightly TSan job scales the load via
//! `REGNDE_STRESS_LANES` / `REGNDE_STRESS_REQS` / `REGNDE_STRESS_ROUNDS`.

use std::sync::Arc;
use std::time::Duration;

use regnde::runtime::{Backend, NativeBackend};
use regnde::serve::{
    BatchPolicy, Batcher, Checkpoint, Client, Registry, Request, Response, Server, ServerOpts,
};
use regnde::util::threadpool::ThreadPool;

const SERVING_POINTS: usize = 6;

fn knob(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Minimal deterministic generator (same constants as `util::rng`'s
/// splitmix-style seeding): good enough to decorrelate lanes, cheap
/// enough to re-run byte-identically under TSan.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 33
    }

    fn unit_f32(&mut self) -> f32 {
        (self.next() % 1000) as f32 / 1000.0
    }
}

fn spiral_checkpoint(be: &NativeBackend) -> Checkpoint {
    let params = be.init_params("spiral_node", 7).unwrap();
    let mut state = be.export_state("spiral_node", &params).unwrap();
    state.step_budget = 100_000;
    let ts: Vec<f32> = (0..SERVING_POINTS)
        .map(|i| i as f32 / (SERVING_POINTS - 1) as f32)
        .collect();
    Checkpoint::new(state, "spiral-node", "vanilla", ts)
}

/// Each test registers its checkpoint under its own model id: the
/// metrics registry is process-global and the harness runs tests in
/// parallel, so per-model counter deltas only reconcile exactly when no
/// other test shares the label.
fn spawn_server(model: &str) -> (String, std::thread::JoinHandle<()>) {
    let be = NativeBackend::new();
    let registry = Arc::new(Registry::in_memory());
    registry.insert(model, spiral_checkpoint(&be)).unwrap();
    let pool = Arc::new(ThreadPool::new(4));
    let batcher = Arc::new(Batcher::new(
        Arc::clone(&registry),
        pool,
        BatchPolicy {
            max_batch: 4,
            // Tiny window: closes constantly while the flood is live, so
            // drain shutdown always lands against an in-flight window.
            max_wait: Duration::from_micros(500),
            ..Default::default()
        },
    ));
    let opts = ServerOpts {
        read_timeout: Duration::from_millis(10),
        ..Default::default()
    };
    let (addr, handle) =
        Server::spawn(Arc::clone(&registry), batcher, opts, "127.0.0.1:0").unwrap();
    (addr.to_string(), handle)
}

/// One lane's tally: how many requests were sent and how each resolved.
#[derive(Default)]
struct LaneTally {
    sent: usize,
    served: usize,
    shed: usize,
    errored: usize,
    /// Connection died (drain raced the write) — allowed only as the
    /// lane's *final* outcome, never with a reply left unread.
    cut: bool,
}

fn run_lane(addr: &str, model: &str, lane: usize, reqs: usize) -> LaneTally {
    let mut tally = LaneTally::default();
    let Ok(mut client) = Client::connect(addr) else {
        // Drain already closed the listener before this lane connected.
        tally.cut = true;
        return tally;
    };
    let mut rng = Lcg(0x5eed ^ ((lane as u64) << 17));
    for _ in 0..reqs {
        let u0 = vec![0.5 + rng.unit_f32(), -0.5 - rng.unit_f32()];
        // Mix tight-but-meetable and effectively-infinite deadlines so
        // the deadline-shed path interleaves with normal serving.
        let deadline_ms = if rng.next() % 4 == 0 { Some(2) } else { Some(10_000) };
        let req = Request::Predict {
            model: model.to_string(),
            u0,
            budget: None,
            deadline_ms,
        };
        tally.sent += 1;
        match client.request(&req) {
            Ok(Response::Predict { traj, nfe, .. }) => {
                // Row-major [T, d] over the serving grid; spiral is 2-d.
                assert_eq!(
                    traj.len(),
                    SERVING_POINTS * 2,
                    "lane {lane}: trajectory length drifted from the serving grid"
                );
                assert!(nfe > 0, "lane {lane}: served reply with zero attempts");
                tally.served += 1;
            }
            Ok(Response::Shed(_)) => tally.shed += 1,
            Ok(Response::Error { msg, .. }) => {
                assert!(!msg.is_empty(), "lane {lane}: error reply with no message");
                tally.errored += 1;
            }
            Ok(other) => panic!("lane {lane}: non-terminal reply to predict: {other:?}"),
            Err(_) => {
                // The drain cut the connection between our write and the
                // reply.  Legal, but only as the last thing a lane sees.
                tally.sent -= 1;
                tally.cut = true;
                return tally;
            }
        }
    }
    tally
}

/// The core scenario: flood from `lanes` clients, shut down mid-flood,
/// and require one-reply-per-request accounting plus a bounded join.
fn flood_and_drain(lanes: usize, reqs: usize) {
    let model = "spiral-drain";
    let (addr, handle) = spawn_server(model);
    let tallies: Vec<LaneTally> = std::thread::scope(|scope| {
        let workers: Vec<_> = (0..lanes)
            .map(|lane| {
                let addr = addr.clone();
                scope.spawn(move || run_lane(&addr, model, lane, reqs))
            })
            .collect();
        // Let the flood establish, then drain from a dedicated lane.
        // The sleep is load-bearing: it puts the shutdown mid-window on
        // every scheduler TSan explores, not after the lanes finish.
        std::thread::sleep(Duration::from_millis(5));
        match Client::connect(&addr).map(|mut c| c.request(&Request::Shutdown)) {
            Ok(Ok(Response::Shutdown)) => {}
            Ok(Ok(other)) => panic!("shutdown got non-shutdown reply: {other:?}"),
            // Listener already closing (a lane's own drain won the race
            // in a previous round's leftover state): nothing to assert.
            Ok(Err(_)) | Err(_) => {}
        }
        workers.into_iter().map(|w| w.join().unwrap()).collect()
    });

    let sent: usize = tallies.iter().map(|t| t.sent).sum();
    let replied: usize = tallies.iter().map(|t| t.served + t.shed + t.errored).sum();
    assert_eq!(
        sent, replied,
        "reply accounting broke: {sent} requests acknowledged by the client \
         lanes but {replied} terminal replies tallied"
    );
    // The server must drain: every in-flight solve answers, the accept
    // loop observes the flag within a poll tick, and the thread joins.
    handle.join().expect("serve thread panicked during drain");

    // Post-drain the port must actually be closed for new work.
    let post = Client::connect(&addr)
        .and_then(|mut c| c.request(&Request::List));
    assert!(post.is_err(), "server still serving after drain: {post:?}");
}

#[test]
fn window_close_vs_drain_shutdown_accounts_for_every_request() {
    let lanes = knob("REGNDE_STRESS_LANES", 4);
    let reqs = knob("REGNDE_STRESS_REQS", 24);
    let rounds = knob("REGNDE_STRESS_ROUNDS", 2);
    for _ in 0..rounds {
        flood_and_drain(lanes, reqs);
    }
}

/// Value of one series in a Prometheus exposition, e.g.
/// `counter_value(&text, "x_total{model=\"m\"}")`.  Missing series read
/// as zero (the family was never touched under that label).
fn series_value(text: &str, series: &str) -> u64 {
    text.lines()
        .find_map(|l| l.strip_prefix(series).and_then(|rest| rest.trim().parse::<f64>().ok()))
        .map(|v| v as u64)
        .unwrap_or(0)
}

#[test]
fn full_flood_without_shutdown_serves_every_request() {
    // Control arm: no drain, so `cut` lanes are a hard failure and every
    // request must resolve.  Distinguishes drain races from plain loss.
    let lanes = knob("REGNDE_STRESS_LANES", 4);
    let reqs = knob("REGNDE_STRESS_REQS", 24);
    let model = "spiral-flood";
    let (addr, handle) = spawn_server(model);
    let tallies: Vec<LaneTally> = std::thread::scope(|scope| {
        let workers: Vec<_> = (0..lanes)
            .map(|lane| {
                let addr = addr.clone();
                scope.spawn(move || run_lane(&addr, model, lane, reqs))
            })
            .collect();
        workers.into_iter().map(|w| w.join().unwrap()).collect()
    });
    for (lane, t) in tallies.iter().enumerate() {
        assert!(!t.cut, "lane {lane}: connection cut without a shutdown in flight");
        assert_eq!(t.sent, reqs, "lane {lane}: short count");
        assert_eq!(t.served + t.shed + t.errored, reqs, "lane {lane}: lost replies");
    }

    // Second ledger (DESIGN.md §Observability): the per-model serving
    // counters scraped over the wire must reconcile EXACTLY with the
    // client-side tallies — this model id belongs to this test alone,
    // so the deltas start from zero.
    let served: usize = tallies.iter().map(|t| t.served).sum();
    let shed: usize = tallies.iter().map(|t| t.shed).sum();
    let errored: usize = tallies.iter().map(|t| t.errored).sum();
    let mut scraper = Client::connect(&addr).unwrap();
    let text = match scraper.request(&Request::Metrics).unwrap() {
        Response::Metrics { text } => text,
        other => panic!("metrics request got {other:?}"),
    };
    let label = format!("{{model=\"{model}\"}}");
    assert_eq!(
        series_value(&text, &format!("regnde_serve_requests_total{label}")),
        (lanes * reqs) as u64,
        "requests counter must equal the flood size:\n{text}"
    );
    assert_eq!(
        series_value(&text, &format!("regnde_serve_served_total{label}")),
        served as u64,
        "served counter must match the lane tallies"
    );
    assert_eq!(
        series_value(&text, &format!("regnde_serve_shed_total{label}")),
        shed as u64,
        "shed counter must match the lane tallies"
    );
    assert_eq!(
        series_value(&text, &format!("regnde_serve_errors_total{label}")),
        errored as u64,
        "error counter must match the lane tallies"
    );
    assert_eq!(
        series_value(&text, &format!("regnde_serve_latency_seconds_count{label}")),
        served as u64,
        "every served reply lands one latency observation"
    );
    assert_eq!(
        series_value(&text, &format!("regnde_serve_request_nfe_count{label}")),
        served as u64,
        "every served reply lands one NFE observation"
    );

    let mut closer = Client::connect(&addr).unwrap();
    assert!(matches!(closer.request(&Request::Shutdown).unwrap(), Response::Shutdown));
    handle.join().expect("serve thread panicked during drain");
}
