//! Fault-injection harness (ISSUE 6 acceptance): every injected fault —
//! solver-level chaos or network-level abuse — surfaces as a typed
//! [`SolveError`], a typed batch/serve error, or a shed reply.  Never a
//! panic, and never a silently-wrong answer.
//!
//! Three layers, matching DESIGN.md §Robustness:
//!
//! 1. **Solver**: [`ChaosSystem`] injects NaN drift, forced rejects and
//!    slow evaluations into ODE and SDE drives and ensembles.
//! 2. **Backend**: all five experiment models take a poisoned (NaN)
//!    parameter vector through `train_step` and `predict` and must
//!    return `Ok` with a typed `Metrics::error`, not panic or `Err`.
//! 3. **Server**: a live loopback server survives malformed frames,
//!    half-written frames, mid-request disconnects and slow dribbled
//!    writes, keeps serving afterwards, and drains — every in-flight
//!    request is answered — before `serve()` returns.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use regnde::data::{mnist_synth, physionet_synth, spiral};
use regnde::runtime::{Backend, NativeBackend, StepCoefs, TrainData, TrainState};
use regnde::serve::{
    BatchError, BatchPolicy, Batcher, Checkpoint, Client, Registry, Request, Response, Server,
    ServerOpts,
};
use regnde::solvers::{
    ode, sde, ChaosConfig, ChaosSystem, OdeSystem, Saveat, SdeSystem, SolveErrorKind,
    SolveOptions, StepBudget,
};
use regnde::util::rng::Rng;
use regnde::util::threadpool::ThreadPool;

// ---------------------------------------------------------------------
// Layer 1: solver chaos
// ---------------------------------------------------------------------

fn spiral_drift(z: &[f64], _t: f64, dz: &mut [f64]) {
    dz[0] = -0.1 * z[0] + 2.0 * z[1];
    dz[1] = -2.0 * z[0] - 0.1 * z[1];
}

#[test]
fn ode_chaos_faults_surface_as_typed_errors_never_panics() {
    // NaN drift at several injection points: NonFiniteState, with the
    // last committed state still finite and stats reflecting real work.
    for at in [0, 3, 17, 40] {
        let mut sys = ChaosSystem::new(OdeSystem(spiral_drift), ChaosConfig::nan_at(at));
        let (saves, out) = ode::drive(
            &mut sys,
            &[2.0, 0.0],
            Saveat::Span { t0: 0.0, t1: 1.5 },
            &SolveOptions::new().with_tolerance(1e-7),
            None,
            &mut [],
        );
        let err = out.unwrap_err();
        assert_eq!(err.kind, SolveErrorKind::NonFiniteState, "at={at}");
        assert!(err.z.iter().all(|v| v.is_finite()), "committed state finite");
        // Grid-shaped partial output: both save points exist even though
        // the solve died mid-span.
        assert_eq!(saves.len(), 2, "failed solves keep grid-shaped saves");
        assert!(saves.iter().flatten().all(|v| v.is_finite()));
    }

    // Forced rejects: the controller either underflows dt or burns the
    // budget — both typed, neither a hang nor a panic.
    let mut sys = ChaosSystem::new(OdeSystem(spiral_drift), ChaosConfig::huge_from(8));
    let (_, out) = ode::drive(
        &mut sys,
        &[2.0, 0.0],
        Saveat::Span { t0: 0.0, t1: 1.5 },
        &SolveOptions::new()
            .with_tolerance(1e-7)
            .with_budget(StepBudget::Total(512)),
        None,
        &mut [],
    );
    let err = out.unwrap_err();
    assert!(
        matches!(
            err.kind,
            SolveErrorKind::StepSizeUnderflow | SolveErrorKind::BudgetExhausted
        ),
        "{:?}",
        err.kind
    );
    assert!(err.stats.nreject > 0, "forced rejects must be visible in stats");

    // Slow evaluations are a latency fault only: bit-identical results.
    let run = |cfg: ChaosConfig| {
        let mut sys = ChaosSystem::new(OdeSystem(spiral_drift), cfg);
        ode::drive(
            &mut sys,
            &[2.0, 0.0],
            Saveat::Span { t0: 0.0, t1: 1.5 },
            &SolveOptions::new().with_tolerance(1e-7),
            None,
            &mut [],
        )
    };
    let (slow_saves, slow) = run(ChaosConfig::slow(5, Duration::from_micros(200)));
    let (clean_saves, clean) = run(ChaosConfig::default());
    assert_eq!(slow_saves, clean_saves, "slow evals must not change the result");
    assert_eq!(slow.unwrap().stats.nfe, clean.unwrap().stats.nfe);
}

#[test]
fn sde_chaos_faults_surface_as_typed_errors_never_panics() {
    let mk = |cfg: ChaosConfig| {
        ChaosSystem::new(
            SdeSystem {
                drift: spiral_drift,
                diffusion: |_z: &[f64], _t: f64, dg: &mut [f64]| dg.fill(0.1),
            },
            cfg,
        )
    };
    // NaN drift mid-solve (diffusion evals interleave, so the counter
    // crosses both callbacks).
    for at in [0, 2, 9] {
        let mut sys = mk(ChaosConfig::nan_at(at));
        let mut rng = Rng::new(7);
        let (saves, out) = sde::drive(
            &mut sys,
            &[1.0, 1.0],
            Saveat::Span { t0: 0.0, t1: 0.5 },
            &mut rng,
            &SolveOptions::new().with_tolerance(1e-3),
            None,
            &mut [],
        );
        let err = out.unwrap_err();
        assert_eq!(err.kind, SolveErrorKind::NonFiniteState, "at={at}");
        assert!(saves.iter().flatten().all(|v| v.is_finite()));
    }
    // Forced rejects under a hard budget.
    let mut sys = mk(ChaosConfig::huge_from(6));
    let mut rng = Rng::new(7);
    let (_, out) = sde::drive(
        &mut sys,
        &[1.0, 1.0],
        Saveat::Span { t0: 0.0, t1: 0.5 },
        &mut rng,
        &SolveOptions::new()
            .with_tolerance(1e-3)
            .with_budget(StepBudget::Total(256)),
        None,
        &mut [],
    );
    let err = out.unwrap_err();
    assert!(
        matches!(
            err.kind,
            SolveErrorKind::StepSizeUnderflow | SolveErrorKind::BudgetExhausted
        ),
        "{:?}",
        err.kind
    );
}

// ---------------------------------------------------------------------
// Layer 2: all five experiment models contain a poisoned parameter
// vector as a typed error
// ---------------------------------------------------------------------

#[test]
fn all_five_models_contain_nan_params_as_typed_errors() {
    let be = NativeBackend::new();

    // Per-model fixture data, matching each arch's TrainData kind.
    let ts_traj: Vec<f32> = (0..12).map(|i| i as f32 / 11.0).collect();
    let traj: Vec<f32> = spiral::spiral_ode_trajectory(
        [2.0, 0.0],
        &ts_traj.iter().map(|&t| t as f64).collect::<Vec<_>>(),
    );

    let ts_sde = spiral::uniform_grid(6, 0.5);
    let ts_sde_f32: Vec<f32> = ts_sde.iter().map(|&t| t as f32).collect();
    let (mu, var) = spiral::spiral_sde_moments([1.0, 1.0], &ts_sde, 16, 1);
    let u0: Vec<f32> = (0..6).flat_map(|_| [1.0f32, 1.0]).collect();

    let b = 3;
    let mut rng = Rng::new(5);
    let x: Vec<f32> = (0..b * mnist_synth::DIM)
        .map(|_| rng.range(0.0, 1.0) as f32)
        .collect();
    let mut y = vec![0.0f32; b * mnist_synth::CLASSES];
    for r in 0..b {
        y[r * mnist_synth::CLASSES + r % mnist_synth::CLASSES] = 1.0;
    }

    let t_pts = 5;
    let c = physionet_synth::CHANNELS;
    let sx: Vec<f32> = (0..b * t_pts * c).map(|_| rng.range(-1.0, 1.0) as f32).collect();
    let mask: Vec<f32> = (0..b * t_pts * c)
        .map(|_| if rng.uniform() < 0.5 { 1.0 } else { 0.0 })
        .collect();
    let ts_series: Vec<f32> = (0..t_pts).map(|i| i as f32 / (t_pts - 1) as f32).collect();

    let cases: Vec<(&str, TrainData)> = vec![
        ("spiral_node", TrainData::Trajectory { data: &traj, ts: &ts_traj }),
        (
            "spiral_nsde",
            TrainData::Moments { u0: &u0, mu: &mu, var: &var, ts: &ts_sde_f32 },
        ),
        ("mnist_node", TrainData::Classify { x: &x, y: &y }),
        ("mnist_nsde", TrainData::Classify { x: &x, y: &y }),
        ("latent_ode", TrainData::Series { x: &sx, mask: &mask, ts: &ts_series }),
    ];

    for (model, data) in &cases {
        let model = *model;
        let info = be.model(model).unwrap();
        let mut params = be.init_params(model, 0).unwrap();
        // Poison every parameter: the first drift (or encoder) pass goes
        // NaN no matter where a given arch reads first.
        params.iter_mut().for_each(|v| *v = f32::NAN);
        let state = TrainState::new(params.clone(), info.opt_state_size);

        let out = be
            .train_step(model, false, 0, &state, data, &StepCoefs::default())
            .unwrap_or_else(|e| panic!("{model}: train_step must contain the fault: {e:#}"));
        assert!(!out.metrics.success, "{model}: poisoned step cannot succeed");
        assert_eq!(
            out.metrics.error,
            Some(SolveErrorKind::NonFiniteState),
            "{model}: typed error must name the failure"
        );

        let (_, m) = be
            .predict(model, &params, data, 0)
            .unwrap_or_else(|e| panic!("{model}: predict must contain the fault: {e:#}"));
        assert_eq!(
            m.error,
            Some(SolveErrorKind::NonFiniteState),
            "{model}: predict carries the same typed error"
        );
    }
}

// ---------------------------------------------------------------------
// Layer 3: live-server chaos + drain guarantee
// ---------------------------------------------------------------------

/// A servable spiral checkpoint; `step_budget` starves the solve when
/// tiny (non-finite parameters are — correctly — rejected at import, so
/// budget exhaustion is the injectable typed solve failure here).
fn spiral_checkpoint(be: &NativeBackend, seed: u32, step_budget: u64) -> Checkpoint {
    let params = be.init_params("spiral_node", seed).unwrap();
    let mut state = be.export_state("spiral_node", &params).unwrap();
    state.step_budget = step_budget;
    let ts: Vec<f32> = (0..6).map(|i| i as f32 / 5.0).collect();
    Checkpoint::new(state, "spiral-node", "vanilla", ts)
}

fn spawn_server(
    max_wait: Duration,
) -> (String, std::thread::JoinHandle<()>, Arc<Registry>) {
    let be = NativeBackend::new();
    let registry = Arc::new(Registry::in_memory());
    registry.insert("spiral", spiral_checkpoint(&be, 3, 100_000)).unwrap();
    registry.insert("poisoned", spiral_checkpoint(&be, 3, 2)).unwrap();
    let pool = Arc::new(ThreadPool::new(4));
    let batcher = Arc::new(Batcher::new(
        Arc::clone(&registry),
        pool,
        BatchPolicy {
            max_batch: 8,
            max_wait,
            ..Default::default()
        },
    ));
    let opts = ServerOpts {
        read_timeout: Duration::from_millis(20),
        ..Default::default()
    };
    let (addr, handle) =
        Server::spawn(Arc::clone(&registry), batcher, opts, "127.0.0.1:0").unwrap();
    (addr.to_string(), handle, registry)
}

/// Read one newline-terminated reply off a raw socket (byte-wise, so a
/// reply split across TCP segments still assembles).
fn read_reply(s: &mut TcpStream) -> String {
    let mut buf = Vec::new();
    let mut byte = [0u8; 1];
    while let Ok(1) = s.read(&mut byte) {
        if byte[0] == b'\n' {
            break;
        }
        buf.push(byte[0]);
    }
    String::from_utf8_lossy(&buf).to_string()
}

fn predict_line(model: &str, deadline_ms: Option<u64>) -> Vec<u8> {
    let mut line = Request::Predict {
        model: model.into(),
        u0: vec![2.0, 0.0],
        budget: None,
        deadline_ms,
    }
    .encode();
    line.push('\n');
    line.into_bytes()
}

#[test]
fn poisoned_model_returns_typed_error_over_the_wire() {
    let (addr, handle, _registry) = spawn_server(Duration::from_micros(200));
    let mut client = Client::connect(&addr).unwrap();
    match client
        .request(&Request::Predict {
            model: "poisoned".into(),
            u0: vec![2.0, 0.0],
            budget: None,
            deadline_ms: None,
        })
        .unwrap()
    {
        Response::Error { kind, msg } => {
            assert_eq!(kind, Some(SolveErrorKind::BudgetExhausted), "{msg}");
        }
        other => panic!("poisoned solve must fail typed, got {other:?}"),
    }
    // The same connection and the healthy model both still work.
    match client
        .request(&Request::Predict {
            model: "spiral".into(),
            u0: vec![2.0, 0.0],
            budget: None,
            deadline_ms: None,
        })
        .unwrap()
    {
        Response::Predict { nfe, .. } => assert!(nfe > 0),
        other => panic!("healthy model must keep serving, got {other:?}"),
    }
    assert!(matches!(client.request(&Request::Shutdown).unwrap(), Response::Shutdown));
    handle.join().unwrap();
}

#[test]
fn network_chaos_never_kills_the_server() {
    let (addr, handle, _registry) = spawn_server(Duration::from_micros(200));
    let line = predict_line("spiral", Some(100));

    for round in 0..3 {
        // Half-written frame, then disconnect.
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(&line[..line.len() / 2]).unwrap();
        drop(s);

        // Garbage frame: must earn an error reply, not a hangup.
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"}{ definitely not json\n").unwrap();
        let reply = read_reply(&mut s);
        let resp = Response::decode(&reply)
            .unwrap_or_else(|e| panic!("round {round}: unparsable reply {reply:?}: {e:#}"));
        assert!(
            matches!(resp, Response::Error { .. }),
            "garbage must earn a typed error, got {resp:?}"
        );
        drop(s);

        // Full request, then vanish before the reply (the server answers
        // a dead peer and must shrug off the write error).
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(&line).unwrap();
        drop(s);

        // Slow dribbled write across several read-timeout ticks: the
        // server must reassemble the frame, not corrupt it.
        let mut s = TcpStream::connect(&addr).unwrap();
        for chunk in line.chunks(7) {
            s.write_all(chunk).unwrap();
            std::thread::sleep(Duration::from_millis(5));
        }
        let reply = read_reply(&mut s);
        assert!(
            reply.contains("\"ok\":true") || reply.contains("\"shed\":true"),
            "dribbled frame must be served or shed, got {reply}"
        );
    }

    // After all that abuse, a clean client still gets a prediction.
    let mut client = Client::connect(&addr).unwrap();
    match client
        .request(&Request::Predict {
            model: "spiral".into(),
            u0: vec![2.0, 0.0],
            budget: None,
            deadline_ms: None,
        })
        .unwrap()
    {
        Response::Predict { nfe, .. } => assert!(nfe > 0),
        other => panic!("server must keep serving after chaos, got {other:?}"),
    }
    assert!(matches!(client.request(&Request::Shutdown).unwrap(), Response::Shutdown));
    handle.join().unwrap();
}

#[test]
fn draining_shutdown_answers_every_in_flight_request() {
    // A slow coalescing window keeps requests in flight long enough for
    // the shutdown to race them; the drain guarantee says every one of
    // them still gets a reply (served or shed — never a dead socket).
    let (addr, handle, _registry) = spawn_server(Duration::from_millis(80));
    let n = 6;
    // Every lane connects before the shutdown fires (barrier), so each
    // request is genuinely in flight on an accepted connection.
    let barrier = std::sync::Barrier::new(n + 1);
    let replies: Vec<Response> = std::thread::scope(|scope| {
        let lanes: Vec<_> = (0..n)
            .map(|i| {
                let addr = addr.clone();
                let barrier = &barrier;
                scope.spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    barrier.wait();
                    client
                        .request(&Request::Predict {
                            model: "spiral".into(),
                            u0: vec![2.0 - 0.01 * i as f32, 0.0],
                            budget: None,
                            deadline_ms: None,
                        })
                        .unwrap_or_else(|e| {
                            panic!("in-flight request {i} must be answered during drain: {e:#}")
                        })
                })
            })
            .collect();
        barrier.wait();
        // Let every lane get its request in flight, then pull the plug.
        std::thread::sleep(Duration::from_millis(25));
        let mut client = Client::connect(&addr).unwrap();
        match client.request(&Request::Shutdown) {
            Ok(Response::Shutdown) => {}
            Ok(other) => panic!("unexpected shutdown reply {other:?}"),
            Err(e) => panic!("shutdown request failed: {e:#}"),
        }
        lanes.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // serve() returns only after the drain: joining here proves it.
    handle.join().unwrap();
    for (i, resp) in replies.iter().enumerate() {
        assert!(
            matches!(resp, Response::Predict { .. } | Response::Shed(_)),
            "request {i}: drained requests are served or shed, got {resp:?}"
        );
    }
    // And new connections are refused once the listener is gone.
    assert!(
        Client::connect(&addr).is_err()
            || Client::connect(&addr)
                .and_then(|mut c| c.request(&Request::List))
                .is_err(),
        "the drained server must not accept new work"
    );
}

#[test]
fn batcher_contains_poisoned_checkpoints_without_wedging() {
    // Direct batcher-level check of the typed Solve error (no sockets):
    // a poisoned window reports the SolveErrorKind; the healthy model is
    // untouched before, during and after.
    let be = NativeBackend::new();
    let registry = Arc::new(Registry::in_memory());
    registry.insert("spiral", spiral_checkpoint(&be, 3, 100_000)).unwrap();
    registry.insert("poisoned", spiral_checkpoint(&be, 3, 2)).unwrap();
    let pool = Arc::new(ThreadPool::new(2));
    let batcher = Batcher::new(Arc::clone(&registry), pool, BatchPolicy::default());

    match batcher.submit("poisoned", vec![2.0, 0.0], None, None) {
        Err(BatchError::Solve { kind, .. }) => {
            assert_eq!(kind, SolveErrorKind::BudgetExhausted)
        }
        other => panic!("expected typed Solve error, got {other:?}"),
    }
    let reply = batcher.submit("spiral", vec![2.0, 0.0], None, None).unwrap();
    assert!(reply.nfe > 0, "healthy model unaffected by the poisoned one");
}
