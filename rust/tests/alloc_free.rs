//! Counting-allocator proof of the solver-core contract (DESIGN.md §Perf):
//! the ODE and SDE accept/reject loops perform **zero heap allocation per
//! step attempt** — allocation count per solve is a constant independent
//! of how many steps the integration takes.
//!
//! This file is its own test binary so the `#[global_allocator]` hook
//! cannot interfere with the rest of the suite, and it contains a single
//! `#[test]` so no concurrent test allocates while we count.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use regnde::models::Mlp;
use regnde::solvers::adjoint::{OdeTape, SdeTape};
use regnde::solvers::ode::SolveOutcome;
use regnde::solvers::problems;
use regnde::solvers::{ode, sde};
use regnde::solvers::{
    OdeSystem, Saveat, SdeSystem, SolveOptions, SolveResultExt, Stats, StepBudget,
};
use regnde::util::rng::Rng;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn count_allocs(f: impl FnOnce()) -> u64 {
    let before = ALLOCS.load(Ordering::SeqCst);
    f();
    ALLOCS.load(Ordering::SeqCst) - before
}

/// Span solve through the unified driver (what the deleted legacy
/// `ode::solve` shim did).
fn solve<F: FnMut(&[f64], f64, &mut [f64])>(
    f: F,
    z0: &[f64],
    t0: f64,
    t1: f64,
    opts: &SolveOptions,
) -> SolveOutcome {
    let mut sys = OdeSystem(f);
    ode::drive(&mut sys, z0, Saveat::Span { t0, t1 }, opts, None, &mut [])
        .1
        .expect("alloc-free test solve failed")
}

/// Taped grid solve with a total budget (the old `solve_saveat_taped`).
fn solve_taped<F: FnMut(&[f64], f64, &mut [f64])>(
    f: F,
    z0: &[f64],
    ts: &[f64],
    opts: &SolveOptions,
    tape: &mut OdeTape,
) -> (Vec<Vec<f64>>, SolveOutcome) {
    let mut sys = OdeSystem(f);
    let (zs, out) = ode::drive(&mut sys, z0, Saveat::Grid(ts), opts, Some(tape), &mut []);
    (zs, out.expect("alloc-free taped solve failed"))
}

/// Grid SDE solve (the old `sde_solve_saveat`), optionally taped.
fn sde_grid<F, G>(
    drift: F,
    diffusion: G,
    z0: &[f64],
    ts: &[f64],
    rng: &mut Rng,
    opts: &SolveOptions,
    tape: Option<&mut SdeTape>,
) -> (Vec<Vec<f64>>, Stats, bool)
where
    F: FnMut(&[f64], f64, &mut [f64]),
    G: FnMut(&[f64], f64, &mut [f64]),
{
    let mut sys = SdeSystem { drift, diffusion };
    let (out, result) = sde::drive(&mut sys, z0, Saveat::Grid(ts), rng, opts, tape, &mut []);
    let ok = result.is_success();
    (out, result.stats(), ok)
}

#[test]
fn step_loop_is_allocation_free() {
    // ---- ODE ----------------------------------------------------------
    let mk = |tol: f64| SolveOptions::new().with_tolerance(tol);
    // Warm-up (lazy runtime init, first-touch effects).
    let _ = solve(problems::spiral_ode, &[2.0, 0.0], 0.0, 1.5, &mk(1e-6));

    let mut steps = [0u64; 2];
    let loose = count_allocs(|| {
        let out = solve(problems::spiral_ode, &[2.0, 0.0], 0.0, 1.5, &mk(1e-3));
        steps[0] = out.stats.attempts();
    });
    let tight = count_allocs(|| {
        let out = solve(problems::spiral_ode, &[2.0, 0.0], 0.0, 1.5, &mk(1e-9));
        steps[1] = out.stats.attempts();
    });
    assert!(
        steps[1] > 4 * steps[0],
        "tight solve must take far more steps ({} vs {})",
        steps[1],
        steps[0]
    );
    // Identical in practice; slack of 8 tolerates stray harness-thread
    // allocations while still ruling out any per-step allocation (the step
    // counts differ by hundreds).
    assert!(
        tight.abs_diff(loose) <= 8,
        "ODE allocation count must not scale with step count \
         ({loose} allocs @ {} steps vs {tight} allocs @ {} steps)",
        steps[0],
        steps[1]
    );

    // ---- SDE ----------------------------------------------------------
    let mk = |tol: f64| SolveOptions::new().with_tolerance(tol);
    let ts = [0.0, 1.0]; // 2 save points: constant save-side allocations
    let mut rng = Rng::new(5);
    let _ = sde_grid(
        problems::spiral_sde_drift,
        problems::spiral_sde_diffusion,
        &[1.0, 1.0],
        &ts,
        &mut rng,
        &mk(1e-2),
        None,
    );

    let mut steps = [0u64; 2];
    let loose = count_allocs(|| {
        let mut rng = Rng::new(6);
        let (_, stats, ok) = sde_grid(
            problems::spiral_sde_drift,
            problems::spiral_sde_diffusion,
            &[1.0, 1.0],
            &ts,
            &mut rng,
            &mk(1e-1),
            None,
        );
        assert!(ok);
        steps[0] = stats.attempts();
    });
    let tight = count_allocs(|| {
        let mut rng = Rng::new(6);
        let (_, stats, ok) = sde_grid(
            problems::spiral_sde_drift,
            problems::spiral_sde_diffusion,
            &[1.0, 1.0],
            &ts,
            &mut rng,
            &mk(1e-4),
            None,
        );
        assert!(ok);
        steps[1] = stats.attempts();
    });
    assert!(
        steps[1] > 4 * steps[0],
        "tight SDE solve must take far more steps ({} vs {})",
        steps[1],
        steps[0]
    );
    assert!(
        tight.abs_diff(loose) <= 8,
        "SDE allocation count must not scale with step count \
         ({loose} allocs @ {} steps vs {tight} allocs @ {} steps)",
        steps[0],
        steps[1]
    );

    // ---- ODE adjoint tape -------------------------------------------------
    // The accept/reject loop stays allocation-free with a tape attached:
    // recording appends into the tape's buffers, so once the tape has
    // grown to capacity (the warm-up solve below), re-running at any
    // tolerance performs a constant number of allocations — zero per
    // step attempt beyond the recorded accepted-step tape.
    let mk = |tol: f64| {
        SolveOptions::new()
            .with_tolerance(tol)
            .with_budget(StepBudget::Total(u64::MAX))
    };
    let ts = [0.0, 1.5];
    let mut tape = OdeTape::new();
    // Warm-up at the tightest tolerance grows the tape to max capacity.
    let _ = solve_taped(problems::spiral_ode, &[2.0, 0.0], &ts, &mk(1e-9), &mut tape);

    let mut steps = [0u64; 2];
    let loose = count_allocs(|| {
        let (_, out) = solve_taped(problems::spiral_ode, &[2.0, 0.0], &ts, &mk(1e-3), &mut tape);
        steps[0] = out.stats.attempts();
    });
    let tight = count_allocs(|| {
        let (_, out) = solve_taped(problems::spiral_ode, &[2.0, 0.0], &ts, &mk(1e-9), &mut tape);
        steps[1] = out.stats.attempts();
    });
    assert!(
        steps[1] > 4 * steps[0],
        "tight taped solve must take far more steps ({} vs {})",
        steps[1],
        steps[0]
    );
    assert!(
        tight.abs_diff(loose) <= 8,
        "taped ODE allocation count must not scale with step count \
         ({loose} allocs @ {} steps vs {tight} allocs @ {} steps)",
        steps[0],
        steps[1]
    );

    // ---- SDE adjoint tape -------------------------------------------------
    let mk = |tol: f64| {
        SolveOptions::new()
            .with_tolerance(tol)
            .with_budget(StepBudget::Total(u64::MAX))
    };
    let mut tape = SdeTape::new();
    {
        let mut rng = Rng::new(6);
        let _ = sde_grid(
            problems::spiral_sde_drift,
            problems::spiral_sde_diffusion,
            &[1.0, 1.0],
            &[0.0, 1.0],
            &mut rng,
            &mk(1e-4),
            Some(&mut tape),
        );
    }
    let mut steps = [0u64; 2];
    let loose = count_allocs(|| {
        let mut rng = Rng::new(6);
        let (_, stats, ok) = sde_grid(
            problems::spiral_sde_drift,
            problems::spiral_sde_diffusion,
            &[1.0, 1.0],
            &[0.0, 1.0],
            &mut rng,
            &mk(1e-1),
            Some(&mut tape),
        );
        assert!(ok);
        steps[0] = stats.attempts();
    });
    let tight = count_allocs(|| {
        let mut rng = Rng::new(6);
        let (_, stats, ok) = sde_grid(
            problems::spiral_sde_drift,
            problems::spiral_sde_diffusion,
            &[1.0, 1.0],
            &[0.0, 1.0],
            &mut rng,
            &mk(1e-4),
            Some(&mut tape),
        );
        assert!(ok);
        steps[1] = stats.attempts();
    });
    assert!(
        steps[1] > 4 * steps[0],
        "tight taped SDE solve must take far more steps ({} vs {})",
        steps[1],
        steps[0]
    );
    assert!(
        tight.abs_diff(loose) <= 8,
        "taped SDE allocation count must not scale with step count \
         ({loose} allocs @ {} steps vs {tight} allocs @ {} steps)",
        steps[0],
        steps[1]
    );

    // ---- Batched MLP kernels ----------------------------------------------
    // The vectorized batched kernels + the fused RK stage-combine keep the
    // contract: driving an MLP vector field through `forward_batch` (which
    // routes every stage evaluation AND the stage combination through
    // `models::kernels`) adds zero per-attempt heap allocations.
    let mlp = Mlp::new(&[16, 64, 16]);
    let rows = 8;
    let theta: Vec<f64> = {
        let mut p32 = vec![0.0f32; mlp.n_params()];
        mlp.init(&mut Rng::new(21), &mut p32);
        p32.iter().map(|&v| v as f64 * 0.5).collect()
    };
    let z0: Vec<f64> = {
        let mut rng = Rng::new(22);
        (0..rows * 16).map(|_| rng.range(-1.0, 1.0)).collect()
    };
    let mk = |tol: f64| SolveOptions::new().with_tolerance(tol);
    let mut steps = [0u64; 2];
    let (loose, tight);
    {
        let mut scratch = mlp.batch_scratch(rows);
        let mut drift =
            |z: &[f64], _t: f64, dz: &mut [f64]| mlp.forward_batch(&theta, z, dz, &mut scratch);
        // Warm-up.
        let _ = solve(&mut drift, &z0, 0.0, 1.5, &mk(1e-6));
        loose = count_allocs(|| {
            let out = solve(&mut drift, &z0, 0.0, 1.5, &mk(1e-3));
            steps[0] = out.stats.attempts();
        });
        tight = count_allocs(|| {
            let out = solve(&mut drift, &z0, 0.0, 1.5, &mk(1e-9));
            steps[1] = out.stats.attempts();
        });
    }
    assert!(
        steps[1] > 4 * steps[0],
        "tight batched-MLP solve must take far more steps ({} vs {})",
        steps[1],
        steps[0]
    );
    assert!(
        tight.abs_diff(loose) <= 8,
        "batched-kernel solve allocation count must not scale with step \
         count ({loose} allocs @ {} steps vs {tight} allocs @ {} steps)",
        steps[0],
        steps[1]
    );

    // ---- Observability taps (DESIGN.md §Observability) --------------------
    // A TraceRecorder attached through the observer slice must not break
    // the contract: its buffer is preallocated at construction, so the
    // allocation count stays constant while the step count scales.
    let mk = |tol: f64| SolveOptions::new().with_tolerance(tol);
    let mut rec = regnde::obs::trace::TraceRecorder::with_capacity(1 << 14);
    {
        // Warm-up with the recorder attached.
        let mut sys = OdeSystem(problems::spiral_ode);
        let _ = ode::drive(
            &mut sys,
            &[2.0, 0.0],
            Saveat::Span { t0: 0.0, t1: 1.5 },
            &mk(1e-6),
            None,
            &mut [&mut rec],
        );
    }
    let mut steps = [0u64; 2];
    let mut naccept = 0u64;
    let loose = count_allocs(|| {
        rec.reset(); // clear() keeps capacity: no allocation
        let mut sys = OdeSystem(problems::spiral_ode);
        let out = ode::drive(
            &mut sys,
            &[2.0, 0.0],
            Saveat::Span { t0: 0.0, t1: 1.5 },
            &mk(1e-3),
            None,
            &mut [&mut rec],
        )
        .1
        .expect("traced solve failed");
        steps[0] = out.stats.attempts();
        naccept = out.stats.naccept;
    });
    assert_eq!(
        rec.steps().len() as u64,
        naccept,
        "recorder captures every accepted step"
    );
    let tight = count_allocs(|| {
        rec.reset();
        let mut sys = OdeSystem(problems::spiral_ode);
        let out = ode::drive(
            &mut sys,
            &[2.0, 0.0],
            Saveat::Span { t0: 0.0, t1: 1.5 },
            &mk(1e-9),
            None,
            &mut [&mut rec],
        )
        .1
        .expect("traced solve failed");
        steps[1] = out.stats.attempts();
    });
    assert!(
        steps[1] > 4 * steps[0],
        "tight traced solve must take far more steps ({} vs {})",
        steps[1],
        steps[0]
    );
    assert!(
        tight.abs_diff(loose) <= 8,
        "TraceRecorder must not add per-step allocation \
         ({loose} allocs @ {} steps vs {tight} allocs @ {} steps)",
        steps[0],
        steps[1]
    );

    // Metrics hot path: handles are resolved once (the registry lookup
    // allocates), after which inc/observe are pure atomics.
    use regnde::obs::metrics;
    let reg = metrics::registry();
    let ctr = reg.counter("alloc_free_test_ops_total");
    let hist = reg.histogram("alloc_free_test_latency_seconds", &metrics::LATENCY_BUCKETS);
    ctr.inc();
    hist.observe(1e-3); // warm-up
    let n = count_allocs(|| {
        for i in 0..1024u64 {
            ctr.inc();
            hist.observe(i as f64 * 1e-4);
        }
    });
    assert_eq!(
        n, 0,
        "Counter::inc / Histogram::observe must be allocation-free ({n} allocs/2048 calls)"
    );

    // Direct check: repeated batched VJP passes allocate nothing at all.
    let mut scratch = mlp.batch_scratch(rows);
    let w: Vec<f64> = {
        let mut rng = Rng::new(23);
        (0..rows * 16).map(|_| rng.range(-1.0, 1.0)).collect()
    };
    let mut gx = vec![0.0; rows * 16];
    let mut gt = vec![0.0; mlp.n_params()];
    // Warm-up pass.
    mlp.vjp_batch(&theta, &z0, &w, &mut gx, &mut gt, &mut scratch);
    let n = count_allocs(|| {
        for _ in 0..64 {
            mlp.vjp_batch(&theta, &z0, &w, &mut gx, &mut gt, &mut scratch);
        }
    });
    assert_eq!(n, 0, "vjp_batch must be allocation-free ({n} allocs/64 calls)");
}
