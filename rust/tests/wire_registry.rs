//! Wire-registry round-trip suite (ISSUE 8, satellite 2).
//!
//! The L3 wire-stability lint (`rust/tools/analyze`) diffs the string
//! literals inside `// analyze: wire(<group>)` items against the
//! committed `wire_registry.txt`.  That proves the *registry* and the
//! *code* agree character-for-character — but not that the strings are
//! semantically live.  This suite closes the loop from the other side:
//! every registered `solve-error-kind` literal must parse back to a
//! `SolveErrorKind` whose `as_str` reproduces it, the `protocol-tags`
//! group must equal `protocol::tags::ALL` exactly, and the
//! `checkpoint-schema` group must match the checkpoint constants.

use std::collections::BTreeSet;
use std::fs;
use std::path::Path;

use regnde::dist::protocol::{frame, tags as dist_tags};
use regnde::serve::checkpoint::{CHECKPOINT_SCHEMA, CHECKPOINT_VERSION, CHECKPOINT_VERSION_V1};
use regnde::serve::protocol::tags;
use regnde::solvers::SolveErrorKind;

/// Every variant, spelled out so adding a variant without touching this
/// test (and the registry) fails the exhaustiveness match below.
const ALL_KINDS: [SolveErrorKind; 6] = [
    SolveErrorKind::NonFiniteState,
    SolveErrorKind::StepSizeUnderflow,
    SolveErrorKind::BudgetExhausted,
    SolveErrorKind::TapeMismatch,
    SolveErrorKind::BadSpan,
    SolveErrorKind::MissingRng,
];

/// Parse `wire_registry.txt` into (group, literal) pairs.  Same grammar
/// as the lint tool's `parse_registry`: `#` comments, blank lines, and
/// one `group: literal` entry per line.
fn registry() -> Vec<(String, String)> {
    // CARGO_MANIFEST_DIR = <repo>/rust for integration tests.
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tools/analyze/wire_registry.txt");
    let text = fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    let mut out = Vec::new();
    for raw in text.lines() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (group, literal) = line
            .split_once(':')
            .unwrap_or_else(|| panic!("malformed registry line {raw:?}"));
        out.push((group.trim().to_string(), literal.trim().to_string()));
    }
    out
}

fn group(name: &str) -> BTreeSet<String> {
    registry()
        .into_iter()
        .filter(|(g, _)| g == name)
        .map(|(_, l)| l)
        .collect()
}

#[test]
fn solve_error_kinds_round_trip_through_the_registry() {
    let declared = group("solve-error-kind");
    // Registry → code: every registered literal parses, and re-encoding
    // reproduces the exact registered string.
    for literal in &declared {
        let kind = SolveErrorKind::parse(literal)
            .unwrap_or_else(|| panic!("registry wire string {literal:?} does not parse"));
        assert_eq!(kind.as_str(), literal, "as_str/parse disagree for {literal:?}");
    }
    // Code → registry: every variant's wire string is registered.
    let emitted: BTreeSet<String> = ALL_KINDS.iter().map(|k| k.as_str().to_string()).collect();
    assert_eq!(emitted, declared, "SolveErrorKind variants drifted from wire_registry.txt");
    assert_eq!(emitted.len(), ALL_KINDS.len(), "duplicate wire strings across variants");
}

#[test]
fn protocol_tags_match_the_registry_exactly() {
    let declared = group("protocol-tags");
    let in_code: BTreeSet<String> = tags::ALL.iter().map(|t| t.to_string()).collect();
    assert_eq!(in_code.len(), tags::ALL.len(), "duplicate entries in tags::ALL");
    assert_eq!(in_code, declared, "protocol tag vocabulary drifted from wire_registry.txt");
    // The ISSUE 10 metrics vocabulary (op value + response payload field).
    for tag in [tags::OP_METRICS, tags::TEXT] {
        assert!(declared.contains(tag), "metrics tag {tag:?} not registered");
    }
}

#[test]
fn checkpoint_schema_constants_are_registered() {
    let declared = group("checkpoint-schema");
    let expected: BTreeSet<String> = [
        CHECKPOINT_SCHEMA.to_string(),
        CHECKPOINT_VERSION.to_string(),
        CHECKPOINT_VERSION_V1.to_string(),
    ]
    .into_iter()
    .collect();
    assert_eq!(expected, declared, "checkpoint schema constants drifted from wire_registry.txt");
}

#[test]
fn dist_tags_and_frame_constants_match_the_registry_exactly() {
    let declared = group("dist");
    let mut in_code: BTreeSet<String> =
        dist_tags::ALL.iter().map(|t| t.to_string()).collect();
    assert_eq!(in_code.len(), dist_tags::ALL.len(), "duplicate entries in dist tags::ALL");
    // The frame constants ride the same `wire(dist)` group; the magic
    // word is registered in its source spelling (`{:#X}` reproduces it).
    in_code.insert(format!("{:#X}", frame::MAGIC));
    for t in frame::ALL_TYPES {
        in_code.insert(t.to_string());
    }
    in_code.insert(frame::METRICS_LEN.to_string());
    assert_eq!(in_code, declared, "dist wire vocabulary drifted from wire_registry.txt");
    // The frame-type bytes must be distinct or decode is ambiguous.
    let bytes: BTreeSet<u8> = frame::ALL_TYPES.iter().copied().collect();
    assert_eq!(bytes.len(), frame::ALL_TYPES.len(), "duplicate frame-type bytes");
}
