//! Property tests for the distributed-training binary frame codec
//! (ISSUE 9, satellite: codec hardening).
//!
//! The codec's contract (DESIGN.md §Distributed): every malformed input
//! — truncated, bit-flipped, oversized, wrong-magic — decodes to a typed
//! [`FrameError`], never a panic; decoding never inspects a byte past
//! the declared frame end; and the `Oversized` cap fires on the header
//! alone, before any payload allocation.  Driven here with the in-tree
//! property framework (`util::propcheck`) over randomized frames and
//! randomized corruption.

use std::io::Cursor;

use regnde::dist::protocol::{frame, read_frame_patient, Frame, FrameBody, FrameError};
use regnde::dist::MAX_FRAME_ELEMS;
use regnde::util::propcheck::{check, ensure, Gen};

/// A random well-formed frame: any type byte, length 0..=64, payload
/// values spanning negatives, subnormal-ish magnitudes and non-finite
/// specials (the codec moves bits, not numbers).
fn gen_frame(g: &mut Gen) -> Frame {
    let ty = frame::ALL_TYPES[g.usize_in(0, frame::ALL_TYPES.len() - 1)];
    let n = g.usize_in(0, 64);
    if ty == frame::METRICS {
        let mut v = g.vec_f64(n, -1e6, 1e6);
        if !v.is_empty() && g.bool() {
            v[0] = f64::NAN;
        }
        Frame {
            ty,
            body: FrameBody::F64(v),
        }
    } else {
        let mut v = g.vec_f32(n, -1e6, 1e6);
        if !v.is_empty() && g.bool() {
            v[0] = f32::INFINITY;
        }
        Frame::f32(ty, v)
    }
}

/// Bitwise frame equality — NaN payloads must round-trip too, so
/// `PartialEq` on the floats is not enough.
fn bits_equal(a: &Frame, b: &Frame) -> bool {
    if a.ty != b.ty {
        return false;
    }
    match (&a.body, &b.body) {
        (FrameBody::F32(x), FrameBody::F32(y)) => {
            x.len() == y.len()
                && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
        }
        (FrameBody::F64(x), FrameBody::F64(y)) => {
            x.len() == y.len()
                && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
        }
        _ => false,
    }
}

#[test]
fn encode_decode_round_trips_bit_exact() {
    check("frame round-trip", 300, |g| {
        let f = gen_frame(g);
        let bytes = f.encode();
        let (back, used) = Frame::decode(&bytes).map_err(|e| format!("decode: {e}"))?;
        ensure(used == bytes.len(), format!("consumed {used} of {}", bytes.len()))?;
        ensure(bits_equal(&f, &back), "payload bits changed in transit")
    });
}

#[test]
fn decode_never_reads_past_the_declared_frame_end() {
    check("no over-read", 300, |g| {
        let f = gen_frame(g);
        let mut bytes = f.encode();
        let frame_len = bytes.len();
        // Arbitrary trailing garbage — including bytes that look like a
        // fresh (corrupt) header — must be left untouched.
        let junk = g.usize_in(1, 64);
        for _ in 0..junk {
            bytes.push(g.usize_in(0, 255) as u8);
        }
        let (back, used) = Frame::decode(&bytes).map_err(|e| format!("decode: {e}"))?;
        ensure(used == frame_len, format!("consumed {used}, frame is {frame_len}"))?;
        ensure(bits_equal(&f, &back), "trailing junk leaked into the payload")
    });
}

#[test]
fn every_truncation_is_a_typed_error() {
    check("truncation", 300, |g| {
        let f = gen_frame(g);
        let bytes = f.encode();
        let cut = g.usize_in(0, bytes.len() - 1);
        match Frame::decode(&bytes[..cut]) {
            Err(FrameError::Truncated { need, got }) => {
                ensure(got == cut, format!("got field {got}, cut at {cut}"))?;
                ensure(need > cut, format!("need {need} <= cut {cut}"))
            }
            Err(other) => Err(format!("expected Truncated, got {other}")),
            Ok(_) => Err(format!("decoded a frame from {cut}/{} bytes", bytes.len())),
        }
    });
}

#[test]
fn every_single_bit_flip_is_a_typed_error() {
    // Exhaustive over one small frame: flipping ANY single bit anywhere
    // in the encoding must surface as a typed error — the type byte and
    // count are checksummed, so even a flip onto another valid type
    // byte cannot silently succeed.
    let f = Frame::f32(frame::GRAD, vec![1.0, -2.5, 3.25]);
    let bytes = f.encode();
    for byte in 0..bytes.len() {
        for bit in 0..8 {
            let mut corrupt = bytes.clone();
            corrupt[byte] ^= 1 << bit;
            match Frame::decode(&corrupt) {
                Ok(_) => panic!("flip {byte}:{bit} decoded successfully"),
                Err(
                    FrameError::BadMagic(_)
                    | FrameError::BadType(_)
                    | FrameError::Oversized { .. }
                    | FrameError::Checksum
                    | FrameError::Truncated { .. },
                ) => {}
                Err(other) => panic!("flip {byte}:{bit}: unexpected error {other}"),
            }
        }
    }
}

#[test]
fn random_bit_flips_on_random_frames_never_panic_or_pass() {
    check("random corruption", 300, |g| {
        let f = gen_frame(g);
        let mut bytes = f.encode();
        let byte = g.usize_in(0, bytes.len() - 1);
        let bit = g.usize_in(0, 7);
        bytes[byte] ^= 1 << bit;
        match Frame::decode(&bytes) {
            Ok(_) => Err(format!("corrupted frame (byte {byte} bit {bit}) decoded")),
            Err(_) => Ok(()),
        }
    });
}

#[test]
fn oversized_counts_are_rejected_before_allocation() {
    // Hand-build headers whose count exceeds the cap; decode must fail
    // with Oversized (not attempt the multi-gigabyte allocation and not
    // report mere truncation).
    check("oversized header", 200, |g| {
        let count = (MAX_FRAME_ELEMS as u32)
            .saturating_add(g.usize_in(1, 1 << 20) as u32);
        let ty = frame::ALL_TYPES[g.usize_in(0, frame::ALL_TYPES.len() - 1)];
        let mut h = Vec::new();
        h.extend_from_slice(&frame::MAGIC.to_le_bytes());
        h.push(ty);
        h.extend_from_slice(&count.to_le_bytes());
        // A few junk payload bytes so the failure cannot be Truncated.
        h.extend_from_slice(&[0u8; 32]);
        match Frame::decode(&h) {
            Err(FrameError::Oversized { count: c, max }) => {
                ensure(c == count, format!("reported count {c}, sent {count}"))?;
                ensure(max == MAX_FRAME_ELEMS, format!("reported cap {max}"))
            }
            Err(other) => Err(format!("expected Oversized, got {other}")),
            Ok(_) => Err("oversized frame decoded".into()),
        }
    });
}

#[test]
fn garbage_magic_is_rejected() {
    check("bad magic", 200, |g| {
        let mut bytes = gen_frame(g).encode();
        let flip = g.usize_in(0, 3);
        bytes[flip] = bytes[flip].wrapping_add(g.usize_in(1, 255) as u8);
        match Frame::decode(&bytes) {
            Err(FrameError::BadMagic(_)) => Ok(()),
            Err(other) => Err(format!("expected BadMagic, got {other}")),
            Ok(_) => Err("frame with corrupted magic decoded".into()),
        }
    });
}

#[test]
fn stream_reads_surface_truncation_as_typed_io() {
    // `read_from` on a stream that ends mid-frame: UnexpectedEof, typed,
    // no panic, and the valid-prefix case decodes the first frame only.
    check("stream truncation", 200, |g| {
        let f = gen_frame(g);
        let bytes = f.encode();
        let cut = g.usize_in(0, bytes.len() - 1);
        match Frame::read_from(&mut Cursor::new(&bytes[..cut])) {
            Err(FrameError::Io(e)) => ensure(
                e.kind() == std::io::ErrorKind::UnexpectedEof,
                format!("kind {:?}", e.kind()),
            ),
            // A cut inside the header can also surface as a header error
            // on exotic prefixes — but only EOF/typed, never success.
            Err(_) => Ok(()),
            Ok(_) => Err(format!("read a frame from {cut}/{} bytes", bytes.len())),
        }
    });
}

#[test]
fn patient_reads_decode_back_to_back_frames() {
    check("patient stream", 100, |g| {
        let a = gen_frame(g);
        let b = gen_frame(g);
        let mut bytes = a.encode();
        bytes.extend_from_slice(&b.encode());
        let mut cur = Cursor::new(bytes);
        let ra = read_frame_patient(&mut cur, || true).map_err(|e| format!("first: {e}"))?;
        let rb = read_frame_patient(&mut cur, || true).map_err(|e| format!("second: {e}"))?;
        ensure(bits_equal(&a, &ra) && bits_equal(&b, &rb), "stream frames drifted")
    });
}
