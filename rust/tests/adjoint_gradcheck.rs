//! Central-finite-difference checks of the discrete adjoint on tiny
//! MLP-dynamics spiral problems (ISSUE 2/3 acceptance criterion: relative
//! error < 1e-4 over the full SRNODE objective
//! `data_loss + coef_e·R_E + coef_s·R_S` on both the ODE and SDE paths,
//! including every coefficient combination with a term switched off).
//!
//! The adjoint differentiates the *discrete program* the solver executed
//! — the accepted `(t, h)` sequence (and, for SDEs, the Brownian
//! increments) held fixed — so the finite differences are taken over
//! [`ode_replay`]/[`sde_replay`], which re-run exactly that program under
//! perturbed parameters and return both replayed accumulators.  In f64
//! the two should agree to ~1e-8; the 1e-4 gate leaves headroom.

use regnde::data::spiral;
use regnde::models::Mlp;
use regnde::solvers::adjoint::{
    ode_backward, ode_replay, sde_backward, sde_replay, OdeTape, SdeTape,
};
use regnde::solvers::{ode, sde};
use regnde::solvers::{OdeSystem, Saveat, SdeSystem, SolveOptions, SolveResultExt, StepBudget};
use regnde::util::rng::Rng;

fn init_f64(mlp: &Mlp, seed: u64) -> Vec<f64> {
    let mut p = vec![0.0f32; mlp.n_params()];
    mlp.init(&mut Rng::new(seed), &mut p);
    p.iter().map(|&v| v as f64).collect()
}

fn rel_err(adj: &[f64], fd: &[f64]) -> f64 {
    let num: f64 = adj
        .iter()
        .zip(fd)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    let den: f64 = fd.iter().map(|b| b * b).sum::<f64>().sqrt();
    num / den.max(1e-12)
}

/// The (coef_e, coef_s) grid every FD check sweeps: plain data loss, each
/// regularizer alone, and the combined SRNODE + ERNODE objective.
const COEF_GRID: [(f64, f64); 4] = [(0.0, 0.0), (0.1, 0.0), (0.0, 0.05), (0.1, 0.05)];

/// ODE: MSE against the Fig.-2 spiral ground truth at 5 save points,
/// swept over the regularizer coefficient grid (full SRNODE objective).
#[test]
fn ode_adjoint_matches_central_differences() {
    let mlp = Mlp::cubed(&[2, 8, 2]);
    let np = mlp.n_params();
    let theta = init_f64(&mlp, 3);

    let ts: Vec<f64> = (0..5).map(|i| i as f64 * 0.75 / 4.0).collect();
    let target = spiral::spiral_ode_trajectory([2.0, 0.0], &ts);
    let ts_count = ts.len();
    let opts = SolveOptions::new()
        .with_tolerance(1e-6)
        .with_budget(StepBudget::Total(1_000_000));

    // Forward solve at the base point records the frozen discrete program.
    let mut tape = OdeTape::new();
    let mut scratch = mlp.scratch();
    let mut sys = OdeSystem(|z: &[f64], _t: f64, dz: &mut [f64]| {
        mlp.forward(&theta, z, dz, &mut scratch)
    });
    let (zs, out) = ode::drive(
        &mut sys,
        &[2.0, 0.0],
        Saveat::Grid(&ts),
        &opts,
        Some(&mut tape),
        &mut [],
    );
    let out = out.expect("base-point forward solve failed");
    assert!(!tape.is_empty());

    // Objective of the frozen program under any parameter vector.
    let denom = (ts_count * 2) as f64;
    let loss = |th: &[f64], coef_e: f64, coef_s: f64| -> f64 {
        let mut s = mlp.scratch();
        let (saves, r_e, r_s) = ode_replay(&tape, &opts.tableau, &[2.0, 0.0], |z, _t, dz| {
            mlp.forward(th, z, dz, &mut s)
        });
        let mut mse = 0.0;
        for (t, z) in saves.iter().enumerate() {
            for k in 0..2 {
                let d = z[k] - target[t * 2 + k] as f64;
                mse += d * d / denom;
            }
        }
        mse + coef_e * r_e + coef_s * r_s
    };

    // Replay at the base point must reproduce the taped forward exactly.
    {
        let mut s = mlp.scratch();
        let (saves, r_e, r_s) = ode_replay(&tape, &opts.tableau, &[2.0, 0.0], |z, _t, dz| {
            mlp.forward(&theta, z, dz, &mut s)
        });
        // The replay recomputes the FSAL stage fresh (the stepper reused
        // the previous step's last stage, whose input differs from znew
        // by rounding only), so agreement is to ulp-accumulation level,
        // not bit-exact.
        for (a, b) in saves.iter().zip(&zs) {
            for k in 0..2 {
                assert!(
                    (a[k] - b[k]).abs() < 1e-10,
                    "replay drifted from the taped forward: {} vs {}",
                    a[k],
                    b[k]
                );
            }
        }
        assert!((r_e - out.stats.r_e).abs() < 1e-9 * out.stats.r_e.max(1e-9));
        assert!(
            (r_s - out.stats.r_s).abs() < 1e-9 * out.stats.r_s.max(1e-9),
            "replayed R_S {r_s} vs forward {}",
            out.stats.r_s
        );
        assert!(r_s > 0.0, "R_S must accumulate on the spiral fit");
    }

    for (coef_e, coef_s) in COEF_GRID {
        // Adjoint gradient.
        let mut save_grads = vec![vec![0.0; 2]; ts_count];
        for (t, z) in zs.iter().enumerate() {
            for k in 0..2 {
                save_grads[t][k] = 2.0 * (z[k] - target[t * 2 + k] as f64) / denom;
            }
        }
        let mut grad = vec![0.0; np];
        let mut sb = mlp.scratch();
        ode_backward(
            &tape,
            &opts.tableau,
            &save_grads,
            coef_e,
            coef_s,
            &mut grad,
            |z: &[f64], _t: f64, w: &[f64], gz: &mut [f64], gp: &mut [f64]| {
                mlp.vjp(&theta, z, w, gz, gp, &mut sb);
            },
        );

        // Central finite differences over every parameter.
        let eps = 1e-5;
        let mut fd = vec![0.0; np];
        for k in 0..np {
            let mut tp = theta.clone();
            tp[k] += eps;
            let mut tm = theta.clone();
            tm[k] -= eps;
            fd[k] = (loss(&tp, coef_e, coef_s) - loss(&tm, coef_e, coef_s)) / (2.0 * eps);
        }

        let err = rel_err(&grad, &fd);
        assert!(
            err < 1e-4,
            "coef_e={coef_e} coef_s={coef_s}: adjoint vs FD relative error \
             {err:.3e} (gate 1e-4)"
        );
    }
}

/// SDE: stochastic-Heun discrete adjoint with the Brownian increments
/// frozen on the tape, against FD of the replayed program.
#[test]
fn sde_adjoint_matches_central_differences() {
    let drift = Mlp::cubed(&[2, 8, 2]);
    let diffusion = Mlp::new(&[2, 4, 2]);
    let n_drift = drift.n_params();
    let n_diff = diffusion.n_params();
    let theta: Vec<f64> = init_f64(&drift, 5)
        .into_iter()
        .chain(init_f64(&diffusion, 6))
        .collect();

    let ts = [0.0, 0.2, 0.4, 0.6];
    let target = [[1.0, 1.0], [0.9, 1.1], [0.8, 1.15], [0.7, 1.2]];
    let opts = SolveOptions::new()
        .with_tolerance(1e-2)
        .with_budget(StepBudget::Total(1_000_000));

    let mut tape = SdeTape::new();
    let mut rng = Rng::new(42);
    let (zs, stats, ok) = {
        let mut sd = drift.scratch();
        let mut sg = diffusion.scratch();
        let mut sys = SdeSystem {
            drift: |z: &[f64], _t: f64, dz: &mut [f64]| {
                drift.forward(&theta[..n_drift], z, dz, &mut sd)
            },
            diffusion: |z: &[f64], _t: f64, dg: &mut [f64]| {
                diffusion.forward(&theta[n_drift..], z, dg, &mut sg)
            },
        };
        let (saves, outcome) = sde::drive(
            &mut sys,
            &[1.0, 1.0],
            Saveat::Grid(&ts),
            &mut rng,
            &opts,
            Some(&mut tape),
            &mut [],
        );
        (saves, outcome.stats(), outcome.is_success())
    };
    assert!(ok && !tape.is_empty());

    let denom = (ts.len() * 2) as f64;
    let loss = |th: &[f64], coef_e: f64, coef_s: f64| -> f64 {
        let mut sd = drift.scratch();
        let mut sg = diffusion.scratch();
        let (saves, r_e, r_s) = sde_replay(
            &tape,
            &[1.0, 1.0],
            |z, _t, dz| drift.forward(&th[..n_drift], z, dz, &mut sd),
            |z, _t, dg| diffusion.forward(&th[n_drift..], z, dg, &mut sg),
        );
        let mut mse = 0.0;
        for (t, z) in saves.iter().enumerate() {
            for k in 0..2 {
                let d = z[k] - target[t][k];
                mse += d * d / denom;
            }
        }
        mse + coef_e * r_e + coef_s * r_s
    };

    // Replay reproduces the taped forward at the base point.
    {
        let mut sd = drift.scratch();
        let mut sg = diffusion.scratch();
        let (saves, r_e, r_s) = sde_replay(
            &tape,
            &[1.0, 1.0],
            |z, _t, dz| drift.forward(&theta[..n_drift], z, dz, &mut sd),
            |z, _t, dg| diffusion.forward(&theta[n_drift..], z, dg, &mut sg),
        );
        for (a, b) in saves.iter().zip(&zs) {
            for k in 0..2 {
                assert!((a[k] - b[k]).abs() < 1e-12, "replay drift from forward");
            }
        }
        assert!((r_e - stats.r_e).abs() < 1e-12);
        assert!(
            (r_s - stats.r_s).abs() < 1e-12 * (1.0 + stats.r_s),
            "replayed R_S {r_s} vs forward {}",
            stats.r_s
        );
        assert!(r_s > 0.0, "R_S must accumulate on the SDE fit");
    }

    for (coef_e, coef_s) in COEF_GRID {
        let mut save_grads = vec![vec![0.0; 2]; ts.len()];
        for (t, z) in zs.iter().enumerate() {
            for k in 0..2 {
                save_grads[t][k] = 2.0 * (z[k] - target[t][k]) / denom;
            }
        }
        let mut grad = vec![0.0; n_drift + n_diff];
        let mut sdb = drift.scratch();
        let mut sgb = diffusion.scratch();
        let mut sdv = drift.scratch();
        let mut sgv = diffusion.scratch();
        sde_backward(
            &tape,
            &save_grads,
            coef_e,
            coef_s,
            &mut grad,
            |z: &[f64], _t: f64, dz: &mut [f64]| {
                drift.forward(&theta[..n_drift], z, dz, &mut sdb)
            },
            |z: &[f64], _t: f64, dg: &mut [f64]| {
                diffusion.forward(&theta[n_drift..], z, dg, &mut sgb)
            },
            |z: &[f64], _t: f64, w: &[f64], gz: &mut [f64], gp: &mut [f64]| {
                drift.vjp(&theta[..n_drift], z, w, gz, &mut gp[..n_drift], &mut sdv);
            },
            |z: &[f64], _t: f64, w: &[f64], gz: &mut [f64], gp: &mut [f64]| {
                diffusion.vjp(&theta[n_drift..], z, w, gz, &mut gp[n_drift..], &mut sgv);
            },
        );

        let eps = 1e-5;
        let mut fd = vec![0.0; n_drift + n_diff];
        for k in 0..n_drift + n_diff {
            let mut tp = theta.clone();
            tp[k] += eps;
            let mut tm = theta.clone();
            tm[k] -= eps;
            fd[k] = (loss(&tp, coef_e, coef_s) - loss(&tm, coef_e, coef_s)) / (2.0 * eps);
        }
        let err = rel_err(&grad, &fd);
        assert!(
            err < 1e-4,
            "coef_e={coef_e} coef_s={coef_s}: SDE adjoint vs FD relative error \
             {err:.3e} (gate 1e-4)"
        );
    }
}
