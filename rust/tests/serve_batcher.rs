//! Micro-batcher acceptance suite (ISSUE 5):
//!
//! * concurrent requests from N threads are **coalesced** (observed
//!   batch sizes > 1 under load),
//! * responses route back to the correct requester (each trajectory
//!   starts at its own request's initial state),
//! * a poisoned/failing solve fails only its own batch's requests —
//!   other models keep serving,
//! * an unbatched request is bit-identical to the in-process
//!   `Backend::predict`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use regnde::runtime::{Backend, NativeBackend, TrainData};
use regnde::serve::{BatchError, BatchPolicy, Batcher, Checkpoint, Registry};
use regnde::util::threadpool::ThreadPool;

const SERVING_POINTS: usize = 8;

fn spiral_checkpoint(step_budget: u64) -> Checkpoint {
    let be = NativeBackend::new();
    let params = be.init_params("spiral_node", 5).unwrap();
    let mut state = be.export_state("spiral_node", &params).unwrap();
    state.step_budget = step_budget;
    let ts: Vec<f32> = (0..SERVING_POINTS)
        .map(|i| i as f32 / (SERVING_POINTS - 1) as f32)
        .collect();
    Checkpoint::new(state, "spiral-node", "vanilla", ts)
}

fn batcher(policy: BatchPolicy) -> (Arc<Registry>, Arc<Batcher>) {
    let registry = Arc::new(Registry::in_memory());
    registry.insert("spiral", spiral_checkpoint(100_000)).unwrap();
    let pool = Arc::new(ThreadPool::new(4));
    let b = Arc::new(Batcher::new(Arc::clone(&registry), pool, policy));
    (registry, b)
}

#[test]
fn concurrent_requests_coalesce_and_route_correctly() {
    let n = 8;
    let policy = BatchPolicy {
        max_batch: n,
        max_wait: Duration::from_millis(100),
        ..Default::default()
    };
    let (_registry, batcher) = batcher(policy);

    let replies: Vec<_> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let batcher = Arc::clone(&batcher);
                scope.spawn(move || {
                    let u0 = vec![1.0 + 0.25 * i as f32, -0.5 * i as f32];
                    (u0.clone(), batcher.submit("spiral", u0, None, None))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let mut max_batch_seen = 0;
    for (u0, reply) in &replies {
        let reply = reply.as_ref().expect("all requests must succeed");
        assert_eq!(reply.traj.len(), SERVING_POINTS * 2);
        // Routing: the trajectory starts exactly at this request's state
        // (the first save point is z0, bit-for-bit).
        assert_eq!(reply.traj[0].to_bits(), u0[0].to_bits());
        assert_eq!(reply.traj[1].to_bits(), u0[1].to_bits());
        assert!(reply.nfe > 0, "NFE accounting must ride every reply");
        assert!(reply.batch >= 1 && reply.batch <= n);
        max_batch_seen = max_batch_seen.max(reply.batch);
    }
    assert!(
        max_batch_seen > 1,
        "8 concurrent requests inside a 100ms window must coalesce \
         (saw max batch {max_batch_seen})"
    );
    // Distinct initial states produce distinct trajectories.
    assert_ne!(replies[0].1.as_ref().unwrap().traj, replies[1].1.as_ref().unwrap().traj);

    let stats = batcher.stats();
    assert_eq!(stats.requests, n as u64);
    assert!(stats.batches < n as u64, "coalescing must reduce batch count");
    assert!(stats.mean_batch() > 1.0);
    assert_eq!(stats.max_batch, max_batch_seen);
}

#[test]
fn max_batch_is_a_hard_cap() {
    let policy = BatchPolicy {
        max_batch: 3,
        max_wait: Duration::from_millis(100),
        ..Default::default()
    };
    let (_registry, batcher) = batcher(policy);
    let replies: Vec<_> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let batcher = Arc::clone(&batcher);
                scope.spawn(move || {
                    batcher.submit("spiral", vec![1.0 + 0.1 * i as f32, 0.5], None, None)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for reply in replies {
        let reply = reply.expect("requests must succeed");
        assert!(reply.batch <= 3, "window exceeded max_batch: {}", reply.batch);
    }
}

#[test]
fn single_request_is_bit_identical_to_in_process_predict() {
    let policy = BatchPolicy {
        max_batch: 4,
        max_wait: Duration::from_micros(100),
        ..Default::default()
    };
    let (registry, batcher) = batcher(policy);
    let model = registry.get("spiral").unwrap();

    let u0 = [2.0f32, 0.0];
    let reply = batcher.submit("spiral", u0.to_vec(), None, None).unwrap();
    assert_eq!(reply.batch, 1);

    // In-process reference: Backend::predict over the same grid (the
    // `data` targets only feed the reported MSE, not the trajectory).
    let be = NativeBackend::new();
    let ts = model.checkpoint.ts.clone();
    let mut data = vec![0.0f32; ts.len() * 2];
    data[0] = u0[0];
    data[1] = u0[1];
    let payload = TrainData::Trajectory { data: &data, ts: &ts };
    let params = model.params();
    let (pred, metrics) = be.predict("spiral_node", params, &payload, 0).unwrap();
    assert_eq!(pred.len(), reply.traj.len());
    for (a, b) in pred.iter().zip(&reply.traj) {
        assert_eq!(a.to_bits(), b.to_bits(), "served and in-process bits differ");
    }
    assert_eq!(metrics.nfe as u64, reply.nfe, "NFE accounting must agree");
}

#[test]
fn failing_solve_poisons_only_its_own_batch() {
    let policy = BatchPolicy {
        max_batch: 4,
        max_wait: Duration::from_millis(50),
        ..Default::default()
    };
    let (registry, batcher) = batcher(policy);
    // A model whose checkpoint budget is too small to finish any solve:
    // every batch that touches it fails.
    registry.insert("tiny", spiral_checkpoint(2)).unwrap();

    let results: Vec<_> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let batcher = Arc::clone(&batcher);
                // Interleave: half the requests hit the poisoned model.
                let id = if i % 2 == 0 { "tiny" } else { "spiral" };
                scope.spawn(move || (id, batcher.submit(id, vec![1.0, 1.0], None, None)))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (id, result) in results {
        match id {
            "tiny" => {
                let err = format!("{:#}", result.expect_err("tiny budget must fail"));
                assert!(err.contains("budget"), "unexpected error: {err}");
            }
            _ => {
                let reply = result.expect("healthy model must keep serving");
                assert!(reply.nfe > 0);
            }
        }
    }

    // And the healthy model still serves after the poisoned batches.
    assert!(batcher.submit("spiral", vec![0.5, 0.5], None, None).is_ok());
}

#[test]
fn shape_and_model_errors_are_rejected_before_batching() {
    let (_registry, batcher) = batcher(BatchPolicy::default());
    let err = batcher.submit("ghost", vec![1.0, 2.0], None, None).unwrap_err();
    assert!(format!("{err:#}").contains("unknown model"));
    let err = batcher.submit("spiral", vec![1.0], None, None).unwrap_err();
    assert!(format!("{err:#}").contains("2-dim"));
    // Non-finite initial states would poison every rider of a window:
    // rejected up front instead.
    let bad = vec![f32::NAN, 0.0];
    let err = batcher.submit("spiral", bad, None, None).unwrap_err();
    assert!(format!("{err:#}").contains("finite"));
    let bad = vec![1.0, f32::INFINITY];
    let err = batcher.submit("spiral", bad, None, None).unwrap_err();
    assert!(format!("{err:#}").contains("finite"));
    // Rejected requests never reach a window.
    assert_eq!(batcher.stats().requests, 0);
}

#[test]
fn underfunded_requests_ride_alone_and_cannot_poison_a_shared_window() {
    // A request declaring a budget below the checkpoint default solves
    // in its own window: its (failing) tiny budget must not drag down
    // concurrent well-budgeted requests for the same model.
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(50),
        ..Default::default()
    };
    let (_registry, batcher) = batcher(policy);
    let results: Vec<_> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let batcher = Arc::clone(&batcher);
                // Even lanes declare a hopeless 1-attempt budget.
                let budget = if i % 2 == 0 { Some(1) } else { None };
                scope.spawn(move || (budget, batcher.submit("spiral", vec![1.0, 1.0], budget, None)))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (budget, result) in results {
        match budget {
            Some(_) => {
                let err = format!("{:#}", result.expect_err("1 attempt cannot finish"));
                assert!(err.contains("budget"), "unexpected error: {err}");
            }
            None => {
                let reply = result.expect("well-budgeted riders must be isolated");
                assert!(reply.batch <= 4, "solo windows must not join the shared one");
            }
        }
    }
}

#[test]
fn expired_deadline_is_shed_at_admission_without_solver_work() {
    let (_registry, batcher) = batcher(BatchPolicy::default());
    let err = batcher
        .submit("spiral", vec![1.0, 0.0], None, Some(Instant::now()))
        .unwrap_err();
    match err {
        BatchError::Shed(reason) => assert!(reason.contains("deadline"), "{reason}"),
        other => panic!("expected Shed, got {other:?}"),
    }
    let stats = batcher.stats();
    assert_eq!(stats.requests, 0, "shed requests never reach a window");
    assert_eq!(stats.shed, 1);
}

#[test]
fn deadline_expiring_during_coalescing_is_shed_at_window_close() {
    let policy = BatchPolicy {
        max_batch: 4,
        max_wait: Duration::from_millis(60),
        ..Default::default()
    };
    let (_registry, batcher) = batcher(policy);
    // The leader holds its window open for 60ms; a 5ms deadline expires
    // while coalescing, so the close sheds the request before solving.
    let err = batcher
        .submit(
            "spiral",
            vec![1.0, 0.0],
            None,
            Some(Instant::now() + Duration::from_millis(5)),
        )
        .unwrap_err();
    assert!(matches!(err, BatchError::Shed(_)), "{err:?}");
    assert_eq!(batcher.stats().shed, 1);
    // The batcher is not wedged: a deadline-less request still solves.
    assert!(batcher.submit("spiral", vec![1.0, 0.0], None, None).is_ok());
}

#[test]
fn full_admission_queue_sheds_instead_of_queueing_unboundedly() {
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(150),
        max_queue: 1,
    };
    let (_registry, batcher) = batcher(policy);
    std::thread::scope(|scope| {
        let leader = {
            let batcher = Arc::clone(&batcher);
            scope.spawn(move || batcher.submit("spiral", vec![1.0, 0.0], None, None))
        };
        // Let the leader open its window, then arrive while it is still
        // coalescing: with max_queue 1 the arrival must shed, not block.
        std::thread::sleep(Duration::from_millis(40));
        let err = batcher
            .submit("spiral", vec![2.0, 0.0], None, None)
            .unwrap_err();
        match err {
            BatchError::Shed(reason) => assert!(reason.contains("queue"), "{reason}"),
            other => panic!("expected Shed, got {other:?}"),
        }
        assert!(
            leader.join().unwrap().is_ok(),
            "the leader itself must still be served"
        );
    });
    assert!(batcher.stats().shed >= 1);
    // Once the window drained, the queue has room again.
    assert!(batcher.submit("spiral", vec![0.5, 0.5], None, None).is_ok());
}
