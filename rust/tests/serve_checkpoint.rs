//! Serving-checkpoint acceptance suite (ISSUE 5):
//!
//! 1. `save → load → predict` is **bit-identical** to the in-memory
//!    model's `predict` on all five experiments' model shapes (the hex
//!    parameter codec must not lose a single f32 bit, and the decoded
//!    state must drive the exact same solve).
//! 2. Malformed, truncated and wrong-version checkpoint files produce
//!    typed [`CheckpointError`]s — never panics.

use std::path::PathBuf;

use regnde::runtime::{Backend, NativeBackend, TrainData};
use regnde::serve::{Checkpoint, CheckpointError};
use regnde::util::rng::Rng;

const IMG_DIM: usize = 784;
const CLASSES: usize = 10;
const SERIES_CHANNELS: usize = 8;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("regnde-ckpt-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A small but valid data payload for every model kind, owned so the
/// borrows in `TrainData` have something to point at.
struct Fixture {
    a: Vec<f32>,
    b: Vec<f32>,
    c: Vec<f32>,
    d: Vec<f32>,
}

fn fixture(model: &str) -> Fixture {
    let mut rng = Rng::new(42);
    match model {
        "spiral_node" => {
            let ts: Vec<f32> = (0..8).map(|i| i as f32 / 7.0).collect();
            let mut data = Vec::with_capacity(ts.len() * 2);
            for i in 0..ts.len() {
                data.push(2.0 - 0.1 * i as f32);
                data.push(0.2 * i as f32);
            }
            Fixture {
                a: data,
                b: ts,
                c: vec![],
                d: vec![],
            }
        }
        "spiral_nsde" => {
            let ts: Vec<f32> = (0..5).map(|i| i as f32 / 4.0).collect();
            let u0: Vec<f32> = (0..4).flat_map(|_| [1.0, 1.0]).collect();
            let mu: Vec<f32> = (0..ts.len() * 2).map(|i| 1.0 - 0.05 * i as f32).collect();
            let var: Vec<f32> = (0..ts.len() * 2).map(|i| 0.01 * (i + 1) as f32).collect();
            Fixture {
                a: u0,
                b: mu,
                c: var,
                d: ts,
            }
        }
        "mnist_node" | "mnist_nsde" => {
            let b = 2;
            let x: Vec<f32> = (0..b * IMG_DIM).map(|_| rng.range(0.0, 1.0) as f32).collect();
            let mut y = vec![0.0f32; b * CLASSES];
            for r in 0..b {
                y[r * CLASSES + r % CLASSES] = 1.0;
            }
            Fixture {
                a: x,
                b: y,
                c: vec![],
                d: vec![],
            }
        }
        "latent_ode" => {
            let (b, t_pts, c) = (2, 5, SERIES_CHANNELS);
            let x: Vec<f32> = (0..b * t_pts * c).map(|_| rng.range(-1.0, 1.0) as f32).collect();
            let mask: Vec<f32> = (0..b * t_pts * c)
                .map(|_| if rng.uniform() < 0.5 { 1.0 } else { 0.0 })
                .collect();
            let ts: Vec<f32> = (0..t_pts).map(|i| i as f32 / (t_pts - 1) as f32).collect();
            Fixture {
                a: x,
                b: mask,
                c: ts,
                d: vec![],
            }
        }
        other => panic!("no fixture for {other}"),
    }
}

fn train_data<'a>(model: &str, f: &'a Fixture) -> TrainData<'a> {
    match model {
        "spiral_node" => TrainData::Trajectory { data: &f.a, ts: &f.b },
        "spiral_nsde" => TrainData::Moments {
            u0: &f.a,
            mu: &f.b,
            var: &f.c,
            ts: &f.d,
        },
        "mnist_node" | "mnist_nsde" => TrainData::Classify { x: &f.a, y: &f.b },
        "latent_ode" => TrainData::Series {
            x: &f.a,
            mask: &f.b,
            ts: &f.c,
        },
        other => panic!("no data for {other}"),
    }
}

#[test]
fn roundtrip_predict_is_bit_identical_on_all_five_model_shapes() {
    let dir = temp_dir("roundtrip");
    let be = NativeBackend::new();
    for model in ["spiral_node", "spiral_nsde", "mnist_node", "mnist_nsde", "latent_ode"] {
        let params = be.init_params(model, 11).unwrap();
        let state = be.export_state(model, &params).unwrap();
        let serving_ts: Vec<f32> = (0..8).map(|i| i as f32 / 7.0).collect();
        let ckpt = Checkpoint::new(state, model, "vanilla", serving_ts);
        let path = dir.join(format!("{model}.json"));
        ckpt.save(&path).unwrap();

        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded, ckpt, "{model}: decoded checkpoint must equal the saved one");
        let restored = be.import_state(&loaded.state).unwrap();
        assert_eq!(restored.len(), params.len(), "{model}");
        for (a, b) in params.iter().zip(&restored) {
            assert_eq!(a.to_bits(), b.to_bits(), "{model}: parameter bits drifted");
        }

        // Same data, same seed: the loaded model's prediction must be
        // the in-memory model's prediction, bit for bit.
        let fix = fixture(model);
        let data = train_data(model, &fix);
        let (out_mem, m_mem) = be.predict(model, &params, &data, 7).unwrap();
        let (out_ckpt, m_ckpt) = be.predict(model, &restored, &data, 7).unwrap();
        assert_eq!(out_mem.len(), out_ckpt.len(), "{model}");
        for (a, b) in out_mem.iter().zip(&out_ckpt) {
            assert_eq!(a.to_bits(), b.to_bits(), "{model}: prediction bits drifted");
        }
        assert_eq!(m_mem.nfe, m_ckpt.nfe, "{model}: NFE must match exactly");
        assert_eq!(m_mem.loss, m_ckpt.loss, "{model}: loss must match exactly");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn export_validates_model_and_shapes() {
    let be = NativeBackend::new();
    assert!(be.export_state("nope", &[0.0; 4]).is_err(), "unknown model");
    assert!(
        be.export_state("spiral_node", &[0.0; 3]).is_err(),
        "wrong parameter count"
    );
    let mut params = be.init_params("spiral_node", 0).unwrap();
    params[0] = f32::NAN;
    assert!(
        be.export_state("spiral_node", &params).is_err(),
        "non-finite parameters must not be exported"
    );
}

#[test]
fn import_rejects_mismatched_states() {
    let be = NativeBackend::new();
    let params = be.init_params("spiral_node", 0).unwrap();
    let mut state = be.export_state("spiral_node", &params).unwrap();

    let mut wrong_model = state.clone();
    wrong_model.model = "mnist_node".into();
    assert!(
        be.import_state(&wrong_model).is_err(),
        "spiral params cannot reconstruct mnist_node"
    );

    let mut wrong_solver = state.clone();
    wrong_solver.solver = "rk4".into();
    assert!(be.import_state(&wrong_solver).is_err(), "unknown solver name");

    state.params[1] = f32::INFINITY;
    assert!(be.import_state(&state).is_err(), "non-finite parameters");
}

#[test]
fn malformed_truncated_and_wrong_version_files_are_typed_errors() {
    let dir = temp_dir("badfiles");
    let be = NativeBackend::new();
    let params = be.init_params("spiral_node", 3).unwrap();
    let state = be.export_state("spiral_node", &params).unwrap();
    let ts: Vec<f32> = (0..4).map(|i| i as f32 / 3.0).collect();
    let ckpt = Checkpoint::new(state, "spiral-node", "ERNODE", ts);
    let good = dir.join("good.json");
    ckpt.save(&good).unwrap();
    let text = std::fs::read_to_string(&good).unwrap();

    // Missing file: Io.
    let err = Checkpoint::load(&dir.join("missing.json")).unwrap_err();
    assert!(matches!(err, CheckpointError::Io(_)), "{err}");

    // Not JSON at all: Parse.
    let p = dir.join("garbage.json");
    std::fs::write(&p, "this is not json").unwrap();
    let err = Checkpoint::load(&p).unwrap_err();
    assert!(matches!(err, CheckpointError::Parse(_)), "{err}");

    // Truncated file (cut mid-object): Parse, not a panic.
    let p = dir.join("truncated.json");
    std::fs::write(&p, &text[..text.len() / 2]).unwrap();
    let err = Checkpoint::load(&p).unwrap_err();
    assert!(matches!(err, CheckpointError::Parse(_)), "{err}");

    // Valid JSON, wrong schema tag.
    let p = dir.join("schema.json");
    std::fs::write(&p, "{\"schema\": \"something-else\", \"version\": 1}").unwrap();
    let err = Checkpoint::load(&p).unwrap_err();
    assert!(matches!(err, CheckpointError::WrongSchema(_)), "{err}");

    // Future format version.
    let p = dir.join("version.json");
    std::fs::write(&p, text.replace("\"version\": 2", "\"version\": 3")).unwrap();
    let err = Checkpoint::load(&p).unwrap_err();
    assert!(
        matches!(err, CheckpointError::WrongVersion { found: 3, .. }),
        "{err}"
    );

    // v1 files (no train block) still load, with train = None.
    let p = dir.join("v1.json");
    std::fs::write(&p, text.replace("\"version\": 2", "\"version\": 1")).unwrap();
    let v1 = Checkpoint::load(&p).unwrap();
    assert_eq!(v1.train, None, "v1 checkpoints carry no resume block");
    assert_eq!(v1.state.params.len(), params.len());

    // Structurally broken: params_hex truncated to a non-multiple of 8.
    let p = dir.join("hex.json");
    let decoded = Checkpoint::load(&good).unwrap();
    let mut j = decoded.to_json();
    if let regnde::util::json::Json::Obj(m) = &mut j {
        let hex = m.get("params_hex").unwrap().as_str().unwrap().to_string();
        let cut = regnde::util::json::Json::Str(hex[..hex.len() - 3].to_string());
        m.insert("params_hex".into(), cut);
    }
    std::fs::write(&p, j.to_string_pretty()).unwrap();
    let err = Checkpoint::load(&p).unwrap_err();
    assert!(matches!(err, CheckpointError::Malformed(_)), "{err}");

    // Missing required field.
    let p = dir.join("missing-field.json");
    let mut j = decoded.to_json();
    if let regnde::util::json::Json::Obj(m) = &mut j {
        m.remove("solver");
    }
    std::fs::write(&p, j.to_string_pretty()).unwrap();
    let err = Checkpoint::load(&p).unwrap_err();
    assert!(matches!(err, CheckpointError::Malformed(_)), "{err}");

    // The good file still loads after all that.
    assert!(Checkpoint::load(&good).is_ok());
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// Property tests (ISSUE 6): random corruption never panics the parser
// ---------------------------------------------------------------------

/// The containment property of `Checkpoint::load`: any byte-level
/// corruption either fails with a typed [`CheckpointError`] (whose
/// Display names the problem) or — when the corruption happens to leave
/// a structurally valid checkpoint, e.g. a bit flip inside the hex
/// parameter payload — loads a checkpoint that the backend can then
/// accept or reject through its own typed path.  Nothing panics.
fn load_is_contained(path: &std::path::Path, what: &str) -> Result<(), String> {
    match Checkpoint::load(path) {
        Err(e) => {
            let msg = e.to_string();
            if msg.is_empty() {
                return Err(format!("{what}: typed error must describe itself"));
            }
            Ok(())
        }
        Ok(loaded) => {
            // Survivor checkpoints must still go through import
            // validation without panicking (Err is fine).
            let be = NativeBackend::new();
            let _ = be.import_state(&loaded.state);
            Ok(())
        }
    }
}

#[test]
fn property_truncated_checkpoints_never_panic() {
    use regnde::util::propcheck::{check, Gen};
    let dir = temp_dir("prop-truncate");
    let be = NativeBackend::new();
    let params = be.init_params("spiral_node", 3).unwrap();
    let state = be.export_state("spiral_node", &params).unwrap();
    let ts: Vec<f32> = (0..4).map(|i| i as f32 / 3.0).collect();
    let good = dir.join("good.json");
    Checkpoint::new(state, "spiral-node", "vanilla", ts).save(&good).unwrap();
    let bytes = std::fs::read(&good).unwrap();
    let p = dir.join("corrupt.json");

    check("checkpoint/truncate", 128, |g: &mut Gen| {
        let cut = g.usize_in(0, bytes.len() - 1);
        std::fs::write(&p, &bytes[..cut]).unwrap();
        load_is_contained(&p, &format!("truncated at {cut}/{}", bytes.len()))
    });
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn property_bit_flipped_checkpoints_never_panic() {
    use regnde::util::propcheck::{check, Gen};
    let dir = temp_dir("prop-bitflip");
    let be = NativeBackend::new();
    let params = be.init_params("spiral_node", 3).unwrap();
    let state = be.export_state("spiral_node", &params).unwrap();
    let ts: Vec<f32> = (0..4).map(|i| i as f32 / 3.0).collect();
    let good = dir.join("good.json");
    Checkpoint::new(state, "spiral-node", "vanilla", ts).save(&good).unwrap();
    let bytes = std::fs::read(&good).unwrap();
    let p = dir.join("corrupt.json");

    check("checkpoint/bitflip", 128, |g: &mut Gen| {
        let mut corrupt = bytes.clone();
        // Flip 1..=8 random bits anywhere in the file (including inside
        // the hex parameter payload and the JSON structure).
        let flips = g.usize_in(1, 8);
        for _ in 0..flips {
            let at = g.usize_in(0, corrupt.len() - 1);
            let bit = g.usize_in(0, 7);
            corrupt[at] ^= 1 << bit;
        }
        std::fs::write(&p, &corrupt).unwrap();
        load_is_contained(&p, &format!("{flips} bit flips"))
    });
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn property_garbage_bytes_are_typed_errors_never_panics() {
    use regnde::util::propcheck::{check, ensure, Gen};
    let dir = temp_dir("prop-garbage");
    let p = dir.join("garbage.json");

    check("checkpoint/garbage", 128, |g: &mut Gen| {
        // Arbitrary bytes, arbitrary length — including invalid UTF-8
        // (must come back as Io, not a panic inside read_to_string).
        let len = g.usize_in(0, 512);
        let junk: Vec<u8> = (0..len).map(|_| g.usize_in(0, 255) as u8).collect();
        std::fs::write(&p, &junk).unwrap();
        match Checkpoint::load(&p) {
            Err(e) => ensure(!e.to_string().is_empty(), "error must describe itself"),
            Ok(_) => ensure(false, format!("{len} random bytes cannot be a checkpoint")),
        }
    });
    std::fs::remove_dir_all(&dir).ok();
}
