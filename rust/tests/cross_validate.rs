//! Cross-validation: the JAX masked-scan adaptive solver (lowered to HLO,
//! executed via PJRT) against the native Rust solver suite on the same IVP.
//!
//! This pins down the semantic equivalence of the two solver stacks — same
//! tableau constants, same error norm / controller — which is what lets the
//! Rust suite serve as ground-truth data generator for the experiments.
//!
//! Requires `--features pjrt`, real xla bindings and compiled artifacts.

#![cfg(feature = "pjrt")]

use regnde::data::spiral;
use regnde::runtime::{Engine, Input};
use regnde::solvers::{ode, OdeSystem, Saveat, SolveOptions};

fn engine() -> Engine {
    Engine::new(regnde::default_artifacts_dir()).expect("artifacts built?")
}

#[test]
fn spiral_trajectory_jax_vs_rust() {
    let engine = engine();
    let ts: Vec<f64> = spiral::uniform_grid(30, 1.5);
    let ts_f32: Vec<f32> = ts.iter().map(|&t| t as f32).collect();

    // JAX path: the lowered spiral_ode_solve artifact (f32, rtol=1e-6).
    let out = engine
        .run(
            "spiral_ode_solve",
            &[Input::F32(&[2.0, 0.0]), Input::F32(&ts_f32)],
        )
        .unwrap();
    let jax_traj = &out[0]; // [30, 2]

    // Rust path: native Tsit5 at the same tolerance.
    let opts = SolveOptions::new().with_tolerance(1e-6);
    let mut sys = OdeSystem(regnde::solvers::problems::spiral_ode);
    let (rust_traj, outcome) =
        ode::drive(&mut sys, &[2.0, 0.0], Saveat::Grid(&ts), &opts, None, &mut []);
    outcome.expect("native reference solve failed");

    for (k, rz) in rust_traj.iter().enumerate() {
        for d in 0..2 {
            let a = jax_traj[k * 2 + d] as f64;
            let b = rz[d];
            assert!(
                (a - b).abs() < 2e-3,
                "t={} dim {d}: jax {a} vs rust {b}",
                ts[k]
            );
        }
    }
}

#[test]
fn jax_solver_stats_are_plausible() {
    let engine = engine();
    let ts: Vec<f32> = (0..30).map(|i| 1.5 * i as f32 / 29.0).collect();
    let out = engine
        .run("spiral_ode_solve", &[Input::F32(&[2.0, 0.0]), Input::F32(&ts)])
        .unwrap();
    let m = regnde::runtime::Metrics::decode(&out[1]).unwrap();
    assert!(m.success, "budget exhausted");
    assert!(m.nfe > 29.0 * 6.0, "at least one step per segment: {}", m.nfe);
    assert!(m.r_s > 0.0 && m.r_e >= 0.0);
    // NFE parity: 6 per attempt + 1 initial (FSAL Tsit5)
    let attempts = m.naccept + m.nreject;
    assert_eq!(m.nfe as u64, 1 + 6 * attempts as u64);
}

#[test]
fn rust_nfe_within_factor_of_jax() {
    // Same tolerance, same method: the two stacks should take a comparable
    // number of f evaluations (f32 vs f64 makes them not identical).
    let engine = engine();
    let ts: Vec<f64> = spiral::uniform_grid(30, 1.5);
    let ts_f32: Vec<f32> = ts.iter().map(|&t| t as f32).collect();
    let out = engine
        .run(
            "spiral_ode_solve",
            &[Input::F32(&[2.0, 0.0]), Input::F32(&ts_f32)],
        )
        .unwrap();
    let m = regnde::runtime::Metrics::decode(&out[1]).unwrap();

    let opts = SolveOptions::new().with_tolerance(1e-6);
    let mut sys = OdeSystem(regnde::solvers::problems::spiral_ode);
    let (_, outcome) =
        ode::drive(&mut sys, &[2.0, 0.0], Saveat::Grid(&ts), &opts, None, &mut []);
    let outcome = outcome.expect("native reference solve failed");
    let ratio = m.nfe / outcome.stats.nfe as f64;
    assert!(
        (0.5..2.0).contains(&ratio),
        "NFE ratio jax/rust = {ratio} ({} vs {})",
        m.nfe,
        outcome.stats.nfe
    );
}
