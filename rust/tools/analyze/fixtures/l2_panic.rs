// Known-bad fixture for L2 panic-freedom (lives at a serve/ pseudo-path,
// so the `[i]`-indexing sub-lint applies too).

fn f(v: &[u8], o: Option<u8>) -> u8 {
    let a = o.unwrap(); // L2.panic
    let b = v[0]; // L2.index
    if a > 3 {
        panic!("boom"); // L2.panic
    }
    // analyze: allow(panic) -- fixture: documented escape hatch
    let c = o.expect("fixture"); // suppressed by the allow above
    // analyze: allow(panic)
    let d = o.unwrap(); // A0.missing-reason above, so this still fires
    a + b + c + d
}

// analyze: allow(index) -- fixture: stale, suppresses nothing
fn g() -> u8 {
    0
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        None::<u8>.unwrap(); // fine: test region
    }
}
