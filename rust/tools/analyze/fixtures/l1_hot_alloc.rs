// Known-bad fixture for L1 hot-path-alloc (rust/tools/analyze).
// Expected findings are asserted line-exactly in tests/fixtures.rs.

// analyze: hot-path
fn hot(v: &mut Vec<f64>, x: f64) -> f64 {
    v.push(x); // L1.alloc: `.push()`
    let s = format!("{x}"); // L1.alloc: `format!`
    let w = v.clone(); // L1.alloc: `.clone()`
    let b = Vec::with_capacity(8); // L1.alloc: `Vec::`
    s.len() as f64 + w.len() as f64 + b.len() as f64
}

// analyze: hot-path
fn hot_clean(acc: &mut [f64], x: f64) -> f64 {
    acc[0] += x; // indexing is L2's business, and util/ is out of L2 scope
    acc[0]
}

fn cold(v: &[f64]) -> Vec<f64> {
    v.to_vec() // fine: not annotated
}

// analyze: hot-path
struct NotAFn; // A0.dangling-hot-path: annotation must precede a fn
