// Known-bad fixture for L3 wire-string stability.  The test pairs this
// with a synthetic registry containing `fixture_tag` and `ghost_tag`.

// analyze: wire(fixture-group)
pub const KNOWN: &str = "fixture_tag";

// analyze: wire(fixture-group)
pub const DRIFTED: &str = "unregistered_tag";

pub const UNTRACKED: &str = "not_extracted"; // not annotated: invisible to L3
