// Fixture: L1.obs — heavyweight observability calls in hot-path fns.
// analyze: hot-path
fn hot_obs(x: f64) -> f64 {
    let h = registry().histogram("lat", &[1.0]);
    h.observe(x);
    let name = labeled("lat", "model", "m");
    span!("step", "ode");
    log_debug!("solver", "x={x}");
    x
}

// analyze: hot-path
fn hot_clean(c: &Counter, h: &Histogram, v: f64) {
    c.inc();
    h.observe(v);
}

// analyze: hot-path
fn hot_allowed() {
    // analyze: allow(obs) -- fixture: handle resolved once at startup
    let _ = registry().counter("c");
}

fn cold() {
    let _ = registry().render();
}
