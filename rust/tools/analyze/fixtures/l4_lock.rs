// Known-bad fixture for L4 lock discipline.  The test pairs this with a
// synthetic order file declaring `queues` rank 10, `stats` rank 20.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Mutex;

struct S {
    queues: Mutex<u32>,
    stats: Mutex<u32>,
    other: Mutex<u32>,
    sock: TcpStream,
}

impl S {
    fn bad_hold(&mut self) {
        let g = self.stats.lock();
        let _ = self.sock.write_all(b"x"); // L4.held: stats guard live
        drop(g);
        let _ = self.sock.write_all(b"y"); // fine: guard dropped
    }

    fn bad_order(&self) {
        let s = self.stats.lock(); // rank 20
        let q = self.queues.lock(); // L4.order: rank 10 under rank 20
        let _ = (s, q);
    }

    fn fine_order(&self) {
        let q = self.queues.lock(); // rank 10
        let s = self.stats.lock(); // fine: ranks ascend
        let _ = (q, s);
    }

    fn undeclared(&self) {
        let o = self.other.lock(); // L4.undeclared
        let _ = o;
    }
}
