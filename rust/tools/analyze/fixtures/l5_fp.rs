// Known-bad fixture for L5 FP determinism (solvers/ pseudo-path).

use std::collections::HashMap; // L5.hash

fn counts(keys: &[u32]) -> HashMap<u32, u32> { // L5.hash (type position)
    let mut m = HashMap::new(); // L5.hash
    for k in keys {
        *m.entry(*k).or_insert(0) += 1;
    }
    m
}

fn bad_sum(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>() // L5.sum: float turbofish
}

fn bad_untyped(v: &[f64]) -> f64 {
    v.iter().sum() // L5.sum: untyped accumulator
}

fn fine_int(v: &[usize]) -> usize {
    v.iter().sum::<usize>() // fine: integer accumulation is exact
}

fn fine_loop(v: &[f64]) -> f64 {
    let mut acc = 0.0;
    for x in v {
        acc += x;
    }
    acc
}
