//! Known-bad fixtures: each lint must fire with exact file:line
//! diagnostics, escapes must suppress, and broken escapes must be
//! findings themselves (DESIGN.md §Static Analysis).

use std::fs;
use std::path::Path;

use regnde_analyze::lints::{
    A0_DANGLING_HOT, A0_MISSING_REASON, A0_STALE_ALLOW, A0_STALE_BASELINE, L1_ALLOC, L1_OBS,
    L2_INDEX, L2_PANIC, L3_WIRE, L4_HELD, L4_ORDER, L4_UNDECLARED, L5_HASH, L5_SUM,
};
use regnde_analyze::{run_sources, BaselineEntry, Config, Finding, RegistryEntry};

fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("fixtures")
        .join(name);
    fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

fn lint(rel: &str, name: &str, cfg: &Config) -> Vec<Finding> {
    run_sources(&[(rel.to_string(), fixture(name))], cfg).findings
}

fn lines(findings: &[Finding]) -> Vec<(usize, &'static str)> {
    findings.iter().map(|f| (f.line, f.lint)).collect()
}

#[test]
fn l1_hot_path_alloc_fires_line_exactly() {
    let cfg = Config::default();
    let found = lint("util/l1_hot_alloc.rs", "l1_hot_alloc.rs", &cfg);
    assert_eq!(
        lines(&found),
        vec![
            (6, L1_ALLOC),
            (7, L1_ALLOC),
            (8, L1_ALLOC),
            (9, L1_ALLOC),
            (23, A0_DANGLING_HOT),
        ]
    );
    assert!(found[0].msg.contains("`.push()` in hot-path fn `hot`"));
    assert!(found[1].msg.contains("`format!`"));
    assert!(found[3].msg.contains("`Vec::`"));
    // Both annotated fns are tracked; the un-annotated one is not.
    let report = run_sources(
        &[("util/l1_hot_alloc.rs".to_string(), fixture("l1_hot_alloc.rs"))],
        &cfg,
    );
    let names: Vec<&str> = report.hot_fns.iter().map(|(_, n)| n.as_str()).collect();
    assert_eq!(names, ["hot", "hot_clean"]);
}

#[test]
fn l1_obs_bans_heavy_observability_in_hot_paths() {
    let cfg = Config::default();
    let found = lint("solvers/l1_obs.rs", "l1_obs.rs", &cfg);
    assert_eq!(
        lines(&found),
        vec![(4, L1_OBS), (6, L1_OBS), (7, L1_OBS), (8, L1_OBS)]
    );
    assert!(found[0].msg.contains("`registry(` in hot-path fn `hot_obs`"));
    assert!(found[1].msg.contains("`labeled(`"));
    assert!(found[2].msg.contains("`span!`"));
    assert!(found[3].msg.contains("`log_debug!`"));
    // The pre-resolved-handle fn is clean, the allow on line 20
    // suppresses line 21, and the cold fn may render freely.
    assert!(!found.iter().any(|f| f.line >= 13), "{found:?}");
}

#[test]
fn l2_panic_freedom_fires_and_allows_suppress() {
    let cfg = Config::default();
    let found = lint("serve/l2_panic.rs", "l2_panic.rs", &cfg);
    assert_eq!(
        lines(&found),
        vec![
            (5, L2_PANIC),
            (6, L2_INDEX),
            (8, L2_PANIC),
            (12, A0_MISSING_REASON),
            (13, L2_PANIC),
            (17, A0_STALE_ALLOW),
        ]
    );
    assert!(found[1].msg.contains("slice indexing"));
    assert!(found[3].msg.contains("needs a reason"));
    assert!(found[5].msg.contains("suppresses nothing"));
    // The documented allow on line 10 suppressed the `.expect()` on the
    // next line: no finding on line 11.
    assert!(!found.iter().any(|f| f.line == 11));
}

#[test]
fn l2_index_is_serve_scoped() {
    // The same source at a solvers/ path: indexing is allowed there, the
    // panic-family lints still fire.
    let cfg = Config::default();
    let found = lint("solvers/l2_panic.rs", "l2_panic.rs", &cfg);
    assert!(found.iter().any(|f| f.lint == L2_PANIC));
    assert!(!found.iter().any(|f| f.lint == L2_INDEX));
    // Line 17's allow(index) is now doubly stale — still reported.
    assert!(found.iter().any(|f| f.line == 17 && f.lint == A0_STALE_ALLOW));
}

#[test]
fn l3_wire_registry_drift_fires_both_directions() {
    let cfg = Config {
        registry: vec![
            RegistryEntry {
                group: "fixture-group".to_string(),
                literal: "fixture_tag".to_string(),
                line: 1,
            },
            RegistryEntry {
                group: "fixture-group".to_string(),
                literal: "ghost_tag".to_string(),
                line: 2,
            },
        ],
        ..Config::default()
    };
    let found = lint("util/l3_wire.rs", "l3_wire.rs", &cfg);
    assert_eq!(found.len(), 2);
    let registry_side = &found[0];
    assert_eq!(
        (registry_side.file.as_str(), registry_side.line, registry_side.lint),
        ("(wire_registry.txt)", 2, L3_WIRE)
    );
    assert!(registry_side.msg.contains("stale registry entry `ghost_tag`"));
    let code_side = &found[1];
    assert_eq!(
        (code_side.file.as_str(), code_side.line, code_side.lint),
        ("util/l3_wire.rs", 8, L3_WIRE)
    );
    assert!(code_side.msg.contains("`unregistered_tag`"));
    assert!(code_side.msg.contains("missing from wire_registry.txt"));
}

#[test]
fn l3_wire_clean_when_registry_matches() {
    let cfg = Config {
        registry: vec![
            RegistryEntry {
                group: "fixture-group".to_string(),
                literal: "fixture_tag".to_string(),
                line: 1,
            },
            RegistryEntry {
                group: "fixture-group".to_string(),
                literal: "unregistered_tag".to_string(),
                line: 2,
            },
        ],
        ..Config::default()
    };
    let found = lint("util/l3_wire.rs", "l3_wire.rs", &cfg);
    assert!(found.is_empty(), "{found:?}");
}

#[test]
fn l4_lock_discipline_fires_line_exactly() {
    let mut cfg = Config::default();
    cfg.order.rank.insert("queues".to_string(), 10);
    cfg.order.rank.insert("stats".to_string(), 20);
    let found = lint("serve/l4_lock.rs", "l4_lock.rs", &cfg);
    assert_eq!(
        lines(&found),
        vec![(18, L4_HELD), (25, L4_ORDER), (36, L4_UNDECLARED)]
    );
    assert!(found[0].msg.contains("`.write_all()` while lock(s) held: stats"));
    assert!(found[1].msg.contains("rank 10"));
    assert!(found[1].msg.contains("rank 20"));
    assert!(found[2].msg.contains("`other`"));
}

#[test]
fn l5_fp_determinism_fires_line_exactly() {
    let cfg = Config::default();
    let found = lint("solvers/l5_fp.rs", "l5_fp.rs", &cfg);
    assert_eq!(
        lines(&found),
        vec![
            (3, L5_HASH),
            (5, L5_HASH),
            (6, L5_HASH),
            (14, L5_SUM),
            (18, L5_SUM),
        ]
    );
    assert!(found[0].msg.contains("BTreeMap"));
    assert!(found[3].msg.contains("float-ambiguous"));
    // Out of scope (serve/ is not reassociation-sensitive): silent.
    assert!(lint("serve/l5_fp.rs", "l5_fp.rs", &cfg).is_empty());
}

#[test]
fn baseline_suppresses_by_file_and_goes_stale() {
    let cfg = Config {
        baseline: vec![
            BaselineEntry {
                lint: L5_SUM.to_string(),
                file: "solvers/l5_fp.rs".to_string(),
                reason: "fixture".to_string(),
                line: 1,
            },
            BaselineEntry {
                lint: L1_ALLOC.to_string(),
                file: "solvers/does_not_exist.rs".to_string(),
                reason: "fixture".to_string(),
                line: 2,
            },
        ],
        ..Config::default()
    };
    let found = lint("solvers/l5_fp.rs", "l5_fp.rs", &cfg);
    assert!(!found.iter().any(|f| f.lint == L5_SUM), "{found:?}");
    assert!(found.iter().any(|f| f.lint == L5_HASH));
    let stale: Vec<&Finding> = found.iter().filter(|f| f.lint == A0_STALE_BASELINE).collect();
    assert_eq!(stale.len(), 1);
    assert_eq!(stale[0].line, 2);
    assert!(stale[0].msg.contains("does_not_exist"));
}
