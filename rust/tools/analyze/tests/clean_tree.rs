//! The committed tree must be lint-clean: zero findings at HEAD, every
//! escape hatch carries a reason, and the annotation surface the other
//! tests rely on (hot fns, wire groups) is actually present.

use std::collections::BTreeSet;
use std::path::Path;

use regnde_analyze::Config;

fn repo_root() -> &'static Path {
    // CARGO_MANIFEST_DIR = <repo>/rust/tools/analyze
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .ancestors()
        .nth(3)
        .expect("repo root above rust/tools/analyze")
}

#[test]
fn tree_is_clean_at_head() {
    let root = repo_root();
    let report = regnde_analyze::run(root).expect("walk rust/src");
    let rendered: Vec<String> = report
        .findings
        .iter()
        .map(|f| format!("{}:{}: {}: {}", f.file, f.line, f.lint, f.msg))
        .collect();
    assert!(
        report.findings.is_empty(),
        "lint findings on a supposedly clean tree:\n{}",
        rendered.join("\n")
    );
}

#[test]
fn hot_path_annotations_cover_the_solver_and_kernel_loops() {
    let report = regnde_analyze::run(repo_root()).expect("walk rust/src");
    assert!(
        report.hot_fns.len() >= 13,
        "expected at least the 13 seeded hot-path fns, got {:?}",
        report.hot_fns
    );
    for (file, name) in [
        ("solvers/ode.rs", "advance"),
        ("solvers/sde.rs", "advance"),
        ("models/kernels.rs", "rk_combine"),
        ("models/kernels.rs", "dense_act"),
        ("models/mlp.rs", "vjp_batch"),
        ("models/mlp.rs", "forward_batch"),
    ] {
        assert!(
            report
                .hot_fns
                .iter()
                .any(|(f, n)| f == file && n == name),
            "missing hot-path annotation on {file}::{name}: {:?}",
            report.hot_fns
        );
    }
}

#[test]
fn wire_extraction_matches_the_committed_registry_exactly() {
    let root = repo_root();
    let report = regnde_analyze::run(root).expect("walk rust/src");
    let cfg = Config::load(&root.join("rust/tools/analyze")).expect("load config");
    // Zero findings (asserted above) already means extracted == registry
    // entry-by-entry; pin the shape so an emptied registry can't pass.
    let total: usize = report.wire_groups.values().sum();
    assert_eq!(total, cfg.registry.len());
    let groups: BTreeSet<&str> = report.wire_groups.keys().map(|g| g.as_str()).collect();
    let declared: BTreeSet<&str> = cfg.registry.iter().map(|e| e.group.as_str()).collect();
    assert_eq!(groups, declared);
    assert_eq!(
        groups,
        BTreeSet::from(["checkpoint-schema", "dist", "protocol-tags", "solve-error-kind"])
    );
}

#[test]
fn allowlist_is_fully_reason_annotated_and_known() {
    let report = regnde_analyze::run(repo_root()).expect("walk rust/src");
    for a in &report.allows {
        assert!(
            !a.reason.trim().is_empty(),
            "allow without a reason at {}:{}",
            a.file,
            a.line
        );
    }
    // The full by-design escape-hatch inventory.  Adding an entry here
    // must be a conscious review decision, same as editing the registry.
    let got: Vec<(&str, &str)> = report
        .allows
        .iter()
        .map(|a| (a.file.as_str(), a.lint))
        .collect();
    assert_eq!(
        got,
        vec![
            ("serve/checkpoint.rs", "L2.index"),
            ("solvers/system.rs", "L2.panic"),
            ("solvers/system.rs", "L2.panic"),
            ("solvers/system.rs", "L2.panic"),
            ("util/threadpool.rs", "L4.held"),
        ]
    );
}
