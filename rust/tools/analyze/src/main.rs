//! CLI for `regnde-analyze` (see lib.rs and DESIGN.md §Static Analysis).
//!
//! ```text
//! cargo run -p regnde-analyze                  # advisory: print findings, exit 0
//! cargo run -p regnde-analyze -- --deny-all    # CI mode: exit 1 on any finding
//! cargo run -p regnde-analyze -- --list-allows # inventory of allow sites
//! cargo run -p regnde-analyze -- --root <dir>  # lint a different checkout
//! ```

use std::env;
use std::path::PathBuf;
use std::process::ExitCode;

fn usage() {
    eprintln!("usage: regnde-analyze [--root <repo>] [--deny-all] [--list-allows]");
}

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut deny = false;
    let mut list_allows = false;
    let mut args = env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(dir) => root = PathBuf::from(dir),
                None => {
                    usage();
                    return ExitCode::from(2);
                }
            },
            "--deny-all" => deny = true,
            "--list-allows" => list_allows = true,
            "--help" | "-h" => {
                usage();
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("regnde-analyze: unknown flag `{other}`");
                usage();
                return ExitCode::from(2);
            }
        }
    }

    let report = match regnde_analyze::run(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("regnde-analyze: {e}");
            return ExitCode::from(3);
        }
    };

    if list_allows {
        for a in &report.allows {
            println!("{}:{} {} -- {}", a.file, a.line, a.lint, a.reason);
        }
        println!("{} allow site(s)", report.allows.len());
        return ExitCode::SUCCESS;
    }

    for f in &report.findings {
        println!("{}:{}: {}: {}", f.file, f.line, f.lint, f.msg);
    }
    let literals: usize = report.wire_groups.values().sum();
    println!(
        "analyze: {} finding(s), {} hot-path fn(s), {} wire literal(s) in {} group(s), \
         {} allow site(s)",
        report.findings.len(),
        report.hot_fns.len(),
        literals,
        report.wire_groups.len(),
        report.allows.len()
    );
    if deny && !report.findings.is_empty() {
        return ExitCode::from(1);
    }
    ExitCode::SUCCESS
}
