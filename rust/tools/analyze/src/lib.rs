//! `regnde-analyze` — a std-only invariant linter for the regnde tree.
//!
//! The repo's headline guarantees (alloc-free step attempts, panic-free
//! serving/solver stacks, stable wire strings, the batcher's lock
//! discipline, FP-deterministic accumulation) are enforced dynamically
//! by tests; this tool enforces them *statically*, so a regression fails
//! CI before a stress test has to get lucky.  Lint catalog, annotation
//! grammar and allowlist policy: `rust/DESIGN.md` §Static Analysis.
//!
//! * **L1 hot-path-alloc** — no allocation inside fns annotated
//!   `// analyze: hot-path`.
//! * **L1.obs** — hot-path fns may only touch the alloc-free
//!   observability surface (a pre-attached recorder or pre-resolved
//!   metric handles): no `registry()`/`labeled()`/`render()` lookups,
//!   no `span!`/`log_*!` macros, per step attempt.
//! * **L2 panic-freedom** — no `unwrap`/`expect`/`panic!`-family (and in
//!   `serve/` no `[i]`-indexing) outside `#[cfg(test)]`, in the scoped
//!   modules.
//! * **L3 wire-string stability** — literals of items annotated
//!   `// analyze: wire(<group>)` must exactly match the committed
//!   `wire_registry.txt`.
//! * **L4 lock discipline** — no blocking call under a live `.lock()`
//!   guard; acquisition order must follow `lock_order.txt`.
//! * **L5 FP-determinism** — no `HashMap`/`HashSet`, no float-ambiguous
//!   `.sum()`/`.product()`, in reassociation-sensitive modules.
//!
//! Per-site escapes are `// analyze: allow(<id>) -- <reason>` (the
//! reason is mandatory and a stale allow is itself a finding); file-level
//! suppressions live in `baseline.txt` (committed empty — the tree is
//! clean — and kept honest by the same staleness rule).

pub mod lexer;
pub mod lints;

use std::collections::BTreeMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use lints::{lint_file, AllowSite, Finding, LockOrder};

/// One `<group>: <literal>` line of `wire_registry.txt`.
#[derive(Clone, Debug)]
pub struct RegistryEntry {
    pub group: String,
    pub literal: String,
    pub line: usize,
}

/// One `<lint> <file> -- <reason>` line of `baseline.txt`.
#[derive(Clone, Debug)]
pub struct BaselineEntry {
    pub lint: String,
    pub file: String,
    pub reason: String,
    pub line: usize,
}

/// Loaded configuration (the three committed files next to the tool).
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub order: LockOrder,
    pub registry: Vec<RegistryEntry>,
    pub baseline: Vec<BaselineEntry>,
}

impl Config {
    /// Load from a `rust/tools/analyze/` directory.  Missing files mean
    /// empty sections (useful for tests; the committed tree has all
    /// three).
    pub fn load(dir: &Path) -> io::Result<Config> {
        let mut cfg = Config::default();
        if let Ok(text) = fs::read_to_string(dir.join("lock_order.txt")) {
            cfg.order = parse_lock_order(&text);
        }
        if let Ok(text) = fs::read_to_string(dir.join("wire_registry.txt")) {
            cfg.registry = parse_registry(&text);
        }
        if let Ok(text) = fs::read_to_string(dir.join("baseline.txt")) {
            cfg.baseline = parse_baseline(&text);
        }
        Ok(cfg)
    }
}

/// Strip a trailing `# comment` and surrounding whitespace.
fn data(line: &str) -> &str {
    line.split('#').next().unwrap_or("").trim()
}

pub fn parse_lock_order(text: &str) -> LockOrder {
    let mut order = LockOrder::default();
    for line in text.lines() {
        let s = data(line);
        if s.is_empty() {
            continue;
        }
        let mut parts = s.split_whitespace();
        let (Some(a), Some(b)) = (parts.next(), parts.next()) else {
            continue;
        };
        if a == "wrapper" {
            order.wrappers.insert(b.to_string());
        } else if let Ok(rank) = a.parse::<i64>() {
            order.rank.insert(b.to_string(), rank);
        }
    }
    order
}

pub fn parse_registry(text: &str) -> Vec<RegistryEntry> {
    let mut entries = Vec::new();
    for (no, line) in text.lines().enumerate() {
        let s = data(line);
        if s.is_empty() {
            continue;
        }
        if let Some((group, literal)) = s.split_once(':') {
            entries.push(RegistryEntry {
                group: group.trim().to_string(),
                literal: literal.trim().to_string(),
                line: no + 1,
            });
        }
    }
    entries
}

pub fn parse_baseline(text: &str) -> Vec<BaselineEntry> {
    let mut entries = Vec::new();
    for (no, line) in text.lines().enumerate() {
        let s = data(line);
        if s.is_empty() {
            continue;
        }
        let (head, reason) = match s.split_once("--") {
            Some((h, r)) => (h.trim(), r.trim()),
            None => (s, ""),
        };
        let mut parts = head.split_whitespace();
        if let (Some(lint), Some(file)) = (parts.next(), parts.next()) {
            entries.push(BaselineEntry {
                lint: lint.to_string(),
                file: file.to_string(),
                reason: reason.to_string(),
                line: no + 1,
            });
        }
    }
    entries
}

/// Aggregated result of a full run.
#[derive(Debug, Default)]
pub struct Report {
    /// Unsuppressed findings, sorted by (file, line, lint).
    pub findings: Vec<Finding>,
    /// Names of all `// analyze: hot-path` annotated fns, per file.
    pub hot_fns: Vec<(String, String)>,
    /// Wire literals extracted per group.
    pub wire_groups: BTreeMap<String, usize>,
    /// Every in-source allow site (all carry reasons by construction).
    pub allows: Vec<AllowSite>,
}

/// Lint a set of `(relative_path, source)` pairs against `cfg` — the
/// whole pipeline minus the filesystem walk, so tests can drive it on
/// fixtures.
pub fn run_sources(sources: &[(String, String)], cfg: &Config) -> Report {
    let mut report = Report::default();
    // (group, literal) -> first (file, line) it was extracted at.
    let mut extracted: BTreeMap<(String, String), (String, usize)> = BTreeMap::new();
    for (rel, src) in sources {
        let out = lint_file(rel, src, &cfg.order);
        for name in out.hot_fns {
            report.hot_fns.push((rel.clone(), name));
        }
        for (group, literal, line) in out.wire {
            extracted
                .entry((group, literal))
                .or_insert_with(|| (rel.clone(), line));
        }
        report.allows.extend(out.allows);
        report.findings.extend(out.findings);
    }
    for ((group, _), _) in extracted.iter() {
        *report.wire_groups.entry(group.clone()).or_insert(0) += 1;
    }
    // L3: extracted vs registry, both directions.
    for ((group, literal), (file, line)) in extracted.iter() {
        let registered = cfg
            .registry
            .iter()
            .any(|e| &e.group == group && &e.literal == literal);
        if !registered {
            report.findings.push(Finding {
                file: file.clone(),
                line: *line,
                lint: lints::L3_WIRE,
                msg: format!("wire string `{literal}` (group {group}) missing from wire_registry.txt"),
            });
        }
    }
    for e in &cfg.registry {
        if !extracted.contains_key(&(e.group.clone(), e.literal.clone())) {
            report.findings.push(Finding {
                file: "(wire_registry.txt)".to_string(),
                line: e.line,
                lint: lints::L3_WIRE,
                msg: format!(
                    "stale registry entry `{}` (group {}): not extracted from any annotated item",
                    e.literal, e.group
                ),
            });
        }
    }
    // Baseline: file-level suppressions, kept honest by staleness.
    let mut used = vec![false; cfg.baseline.len()];
    report.findings.retain(|f| {
        for (k, b) in cfg.baseline.iter().enumerate() {
            if b.lint == f.lint && b.file == f.file {
                used[k] = true;
                return false;
            }
        }
        true
    });
    for (k, b) in cfg.baseline.iter().enumerate() {
        if !used[k] {
            report.findings.push(Finding {
                file: "(baseline.txt)".to_string(),
                line: b.line,
                lint: lints::A0_STALE_BASELINE,
                msg: format!(
                    "baseline entry `{} {}` suppresses nothing (remove it)",
                    b.lint, b.file
                ),
            });
        }
    }
    report
        .findings
        .sort_by(|a, b| (&a.file, a.line, a.lint).cmp(&(&b.file, b.line, b.lint)));
    report.hot_fns.sort();
    report.allows.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    report
}

/// Collect every `.rs` file under `dir`, sorted, as paths relative to it.
fn collect_sources(dir: &Path) -> io::Result<Vec<(String, String)>> {
    let mut files: Vec<PathBuf> = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        for entry in fs::read_dir(&d)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                files.push(path);
            }
        }
    }
    files.sort();
    let mut sources = Vec::with_capacity(files.len());
    for path in files {
        let rel = path
            .strip_prefix(dir)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        sources.push((rel, fs::read_to_string(&path)?));
    }
    Ok(sources)
}

/// Full run rooted at the repo checkout: lints `<root>/rust/src` against
/// the config in `<root>/rust/tools/analyze`.
pub fn run(root: &Path) -> io::Result<Report> {
    let src = root.join("rust").join("src");
    if !src.is_dir() {
        return Err(io::Error::new(
            io::ErrorKind::NotFound,
            format!("{} is not a directory (pass --root <repo>)", src.display()),
        ));
    }
    let cfg = Config::load(&root.join("rust").join("tools").join("analyze"))?;
    let sources = collect_sources(&src)?;
    Ok(run_sources(&sources, &cfg))
}
