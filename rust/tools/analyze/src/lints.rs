//! The lint passes (L1–L5) plus the annotation/allow machinery (A0).
//!
//! Everything operates on the token stream from [`crate::lexer`]; the
//! little structure the passes need — attributes, item extents, brace
//! depth, `fn` bodies, `#[cfg(test)]` regions — is recovered here.  The
//! lint catalog, annotation grammar and scope policy are documented
//! normatively in `rust/DESIGN.md` §Static Analysis.

use std::collections::{BTreeMap, BTreeSet};

use crate::lexer::{Kind, Tok};

pub const L1_ALLOC: &str = "L1.alloc";
pub const L1_OBS: &str = "L1.obs";
pub const L2_PANIC: &str = "L2.panic";
pub const L2_INDEX: &str = "L2.index";
pub const L3_WIRE: &str = "L3.wire";
pub const L4_HELD: &str = "L4.held";
pub const L4_ORDER: &str = "L4.order";
pub const L4_UNDECLARED: &str = "L4.undeclared";
pub const L5_HASH: &str = "L5.hash";
pub const L5_SUM: &str = "L5.sum";
pub const A0_UNKNOWN: &str = "A0.unknown-annotation";
pub const A0_MISSING_REASON: &str = "A0.missing-reason";
pub const A0_DANGLING_HOT: &str = "A0.dangling-hot-path";
pub const A0_STALE_ALLOW: &str = "A0.stale-allow";
pub const A0_STALE_BASELINE: &str = "A0.stale-baseline";

const INT_TYPES: &[&str] = &[
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize",
];
const ALLOC_METHODS: &[&str] = &["push", "collect", "to_vec", "clone", "to_string", "to_owned"];
const ALLOC_MACROS: &[&str] = &["format", "vec"];
const ALLOC_PATHS: &[&str] = &["Vec", "Box", "String"];
const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];
/// Observability calls that allocate or lock (registry lookup, label
/// formatting, exposition/trace rendering): banned in hot-path fns,
/// which may only touch the alloc-free surface — a pre-attached
/// `TraceRecorder` or handles resolved outside the loop.
const OBS_HEAVY_CALLS: &[&str] = &["registry", "labeled", "render", "dump_chrome_trace", "note_train_step"];
/// Logging formats to stderr and `span!` takes timestamps + a buffer
/// lock on drop: phase-granularity only (DESIGN.md §Observability),
/// never per step attempt.
const OBS_MACROS: &[&str] = &["log_error", "log_warn", "log_info", "log_debug", "span"];
const IO_CALLS: &[&str] = &[
    "write",
    "write_all",
    "write_fmt",
    "flush",
    "read",
    "read_line",
    "read_exact",
    "read_to_end",
    "read_to_string",
    "accept",
    "connect",
    "incoming",
    "recv",
    "recv_timeout",
    "sleep",
    "join",
    "wait",
    "wait_timeout",
    "drive",
    "predict",
    "predict_batch",
    "predict_traj_batch",
    "solve",
    "submit",
];
const ITEM_TERMINATORS: &[&str] = &["struct", "enum", "mod", "trait", "use", "static", "impl"];
const SKIP_BEFORE_FN: &[&str] = &["pub", "crate", "in", "unsafe", "const", "extern", "async"];

fn allow_lint(id: &str) -> Option<&'static str> {
    match id {
        "alloc" => Some(L1_ALLOC),
        "obs" => Some(L1_OBS),
        "panic" => Some(L2_PANIC),
        "index" => Some(L2_INDEX),
        "held" => Some(L4_HELD),
        "order" => Some(L4_ORDER),
        "undeclared" => Some(L4_UNDECLARED),
        "hash" => Some(L5_HASH),
        "sum" => Some(L5_SUM),
        "wire" => Some(L3_WIRE),
        _ => None,
    }
}

/// One diagnostic.  `file` is the path relative to `rust/src/` (or a
/// pseudo-file like `(wire_registry.txt)` for registry-side findings).
#[derive(Clone, Debug)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub lint: &'static str,
    pub msg: String,
}

/// One `// analyze: allow(<id>) -- reason` site.
#[derive(Clone, Debug)]
pub struct AllowSite {
    pub file: String,
    pub line: usize,
    pub lint: &'static str,
    pub reason: String,
}

/// Which lints apply to a file, by its path relative to `rust/src/`.
///
/// * L1 and L3 are annotation-driven and run everywhere.
/// * L2 guards the panic-free stacks: `serve/`, `solvers/`, `dist/`,
///   `runtime/native.rs` and the CLI in `main.rs`.  The `[i]`-indexing
///   sub-lint is scoped to `serve/` and `dist/` only — the solver
///   numeric kernels index by construction over lengths they allocated,
///   while `serve/` and `dist/` handle untrusted wire input (DESIGN.md
///   §Static Analysis).
/// * L4 covers the lock-holding modules: `serve/` + `util/threadpool.rs`.
/// * L5 covers the reassociation-sensitive numerics: `solvers/` +
///   `models/`.
pub struct Scope {
    pub l2: bool,
    pub l2_index: bool,
    pub l4: bool,
    pub l5: bool,
}

pub fn scope_for(rel: &str) -> Scope {
    let serve = rel.starts_with("serve/");
    let solvers = rel.starts_with("solvers/");
    let dist = rel.starts_with("dist/");
    // The observability layer sits on every panic-free stack (metric
    // taps run inside serve/dist/train) and its registry iteration
    // feeds the deterministic exposition, so it inherits L2 and L5.
    let obs = rel.starts_with("obs/");
    Scope {
        l2: serve || solvers || dist || obs || rel == "runtime/native.rs" || rel == "main.rs",
        l2_index: serve || dist,
        l4: serve || rel == "util/threadpool.rs",
        l5: solvers || rel.starts_with("models/") || obs,
    }
}

/// Lock-order declarations from `lock_order.txt`.
#[derive(Clone, Debug, Default)]
pub struct LockOrder {
    /// lock name -> rank (lower rank must be acquired first).
    pub rank: BTreeMap<String, i64>,
    /// Wrapper functions whose internal `.lock()` is skipped and whose
    /// call sites count as acquisitions of their last argument ident.
    pub wrappers: BTreeSet<String>,
}

/// Per-file lint result before cross-file aggregation.
#[derive(Debug, Default)]
pub struct FileReport {
    pub findings: Vec<Finding>,
    pub hot_fns: Vec<String>,
    /// (group, literal, line) extracted from `// analyze: wire(<group>)`
    /// annotated items.
    pub wire: Vec<(String, String, usize)>,
    pub allows: Vec<AllowSite>,
}

struct Hot {
    name: String,
    start: usize,
    end: usize,
}

struct Allow {
    line: usize,
    lint: &'static str,
    reason: String,
    used: bool,
}

struct Guard {
    rank: i64,
    lock: String,
    name: Option<String>,
    depth: i64,
    temp: bool,
}

/// `toks[i]` is the `#` of an attribute: collect its identifiers and
/// return the index one past the closing `]`.
fn attr_idents(toks: &[Tok], i: usize) -> (Vec<String>, usize) {
    let mut idents = Vec::new();
    let mut depth = 0i64;
    let mut j = i + 1;
    while j < toks.len() {
        let t = &toks[j];
        if t.kind == Kind::Punct && t.text == "[" {
            depth += 1;
        } else if t.kind == Kind::Punct && t.text == "]" {
            depth -= 1;
            if depth == 0 {
                return (idents, j + 1);
            }
        } else if t.kind == Kind::Ident {
            idents.push(t.text.clone());
        }
        j += 1;
    }
    (idents, toks.len())
}

/// Extent of the item starting at token `i`: index one past its
/// terminating `;` (at bracket depth 0) or its matching closing `}`.
fn item_extent(toks: &[Tok], i: usize) -> usize {
    let mut depth = 0i64;
    let mut j = i;
    while j < toks.len() {
        let t = &toks[j];
        if t.kind == Kind::Punct {
            match t.text.as_str() {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => {
                    depth -= 1;
                    if depth == 0 && t.text == "}" {
                        return j + 1;
                    }
                }
                ";" if depth == 0 => return j + 1,
                _ => {}
            }
        }
        j += 1;
    }
    toks.len()
}

fn skip_attrs_and_comments(toks: &[Tok], mut i: usize) -> usize {
    while i < toks.len() {
        let t = &toks[i];
        if t.kind == Kind::Comment {
            i += 1;
        } else if t.kind == Kind::Punct && t.text == "#" {
            let (_, next) = attr_idents(toks, i);
            i = next;
        } else {
            break;
        }
    }
    i
}

struct FilePass<'a> {
    rel: &'a str,
    toks: &'a [Tok],
    test_mask: Vec<bool>,
    /// Name of the innermost enclosing `fn` per token (index into
    /// `fn_names`), for the L4 wrapper exclusion.
    fn_of: Vec<Option<usize>>,
    fn_names: Vec<String>,
    findings: Vec<Finding>,
    allows: Vec<Allow>,
    hot: Vec<Hot>,
    wire: Vec<(String, String, usize)>,
}

impl<'a> FilePass<'a> {
    fn new(rel: &'a str, toks: &'a [Tok]) -> Self {
        let mut p = FilePass {
            rel,
            toks,
            test_mask: vec![false; toks.len()],
            fn_of: vec![None; toks.len()],
            fn_names: Vec::new(),
            findings: Vec::new(),
            allows: Vec::new(),
            hot: Vec::new(),
            wire: Vec::new(),
        };
        p.mark_tests();
        p.mark_fns();
        p.collect_annotations();
        p
    }

    fn emit(&mut self, line: usize, lint: &'static str, msg: String) {
        self.findings.push(Finding {
            file: self.rel.to_string(),
            line,
            lint,
            msg,
        });
    }

    fn prev(&self, i: usize) -> Option<&Tok> {
        if i == 0 {
            None
        } else {
            self.toks.get(i - 1)
        }
    }

    fn prev_is(&self, i: usize, text: &str) -> bool {
        self.prev(i).is_some_and(|t| t.kind == Kind::Punct && t.text == text)
    }

    fn next_is(&self, i: usize, text: &str) -> bool {
        self.toks
            .get(i + 1)
            .is_some_and(|t| t.kind == Kind::Punct && t.text == text)
    }

    /// `#[test]` / `#[cfg(test)]` attributes mask the following item.
    fn mark_tests(&mut self) {
        let toks = self.toks;
        let mut i = 0usize;
        while i < toks.len() {
            let t = &toks[i];
            if t.kind == Kind::Punct && t.text == "#" && self.next_is(i, "[") {
                let (idents, j) = attr_idents(toks, i);
                let is_test = idents == ["test"] || (idents.len() == 2 && idents[0] == "cfg" && idents[1] == "test");
                if is_test {
                    let start = skip_attrs_and_comments(toks, j);
                    let end = item_extent(toks, start);
                    for m in self.test_mask[i..end].iter_mut() {
                        *m = true;
                    }
                    i = end;
                    continue;
                }
                i = j;
                continue;
            }
            i += 1;
        }
    }

    fn mark_fns(&mut self) {
        let toks = self.toks;
        let mut stack: Vec<(usize, i64)> = Vec::new();
        let mut depth = 0i64;
        let mut pending: Option<usize> = None;
        for i in 0..toks.len() {
            let t = &toks[i];
            if t.kind == Kind::Ident && t.text == "fn" {
                if let Some(next) = toks.get(i + 1) {
                    if next.kind == Kind::Ident {
                        self.fn_names.push(next.text.clone());
                        pending = Some(self.fn_names.len() - 1);
                    }
                }
            }
            if t.kind == Kind::Punct && t.text == "{" {
                depth += 1;
                if let Some(idx) = pending.take() {
                    stack.push((idx, depth));
                }
            }
            if t.kind == Kind::Punct && t.text == "}" {
                if stack.last().is_some_and(|&(_, d)| d == depth) {
                    stack.pop();
                }
                depth -= 1;
            }
            self.fn_of[i] = stack.last().map(|&(idx, _)| idx);
        }
    }

    fn collect_annotations(&mut self) {
        let toks = self.toks;
        let mut pending_hot: Option<usize> = None; // annotation line
        let mut pending_wire: Option<(String, usize)> = None; // (group, line)
        let mut i = 0usize;
        while i < toks.len() {
            let t = &toks[i];
            if t.kind == Kind::Comment {
                let txt = t.text.trim();
                if let Some(body) = txt.strip_prefix("analyze:") {
                    let body = body.trim();
                    if body == "hot-path" {
                        pending_hot = Some(t.line);
                    } else if let Some(rest) = body.strip_prefix("allow(") {
                        self.parse_allow(rest, t.line, txt);
                    } else if let Some(rest) = body.strip_prefix("wire(") {
                        match parse_group(rest) {
                            Some(group) => pending_wire = Some((group, t.line)),
                            None => {
                                self.emit(t.line, A0_UNKNOWN, format!("unparsable annotation `{txt}`"));
                            }
                        }
                    } else {
                        self.emit(t.line, A0_UNKNOWN, format!("unknown annotation `{txt}`"));
                    }
                }
                i += 1;
                continue;
            }
            if let Some(hline) = pending_hot {
                let is_skip = t.kind == Kind::Punct
                    || (t.kind == Kind::Ident && SKIP_BEFORE_FN.contains(&t.text.as_str()));
                if is_skip {
                    // attribute / visibility tokens between annotation and fn
                } else if t.kind == Kind::Ident && t.text == "fn" {
                    let name = toks
                        .get(i + 1)
                        .filter(|n| n.kind == Kind::Ident)
                        .map(|n| n.text.clone())
                        .unwrap_or_else(|| "?".to_string());
                    // Body start: first `{` at paren/bracket depth 0.
                    let mut d = 0i64;
                    let mut j = i;
                    while j < toks.len() {
                        let tj = &toks[j];
                        if tj.kind == Kind::Punct {
                            match tj.text.as_str() {
                                "(" | "[" => d += 1,
                                ")" | "]" => d -= 1,
                                "{" if d == 0 => break,
                                _ => {}
                            }
                        }
                        j += 1;
                    }
                    let end = if j < toks.len() {
                        item_extent(toks, j)
                    } else {
                        toks.len()
                    };
                    self.hot.push(Hot {
                        name,
                        start: j,
                        end,
                    });
                    pending_hot = None;
                } else if t.kind == Kind::Ident && ITEM_TERMINATORS.contains(&t.text.as_str()) {
                    self.emit(
                        hline,
                        A0_DANGLING_HOT,
                        "hot-path annotation is not followed by a fn".to_string(),
                    );
                    pending_hot = None;
                }
            }
            if let Some((group, _)) = pending_wire.take() {
                let end = item_extent(toks, i);
                for tok in &toks[i..end] {
                    if tok.kind == Kind::Str || tok.kind == Kind::Num {
                        self.wire.push((group.clone(), tok.text.clone(), tok.line));
                    }
                }
            }
            i += 1;
        }
    }

    fn parse_allow(&mut self, rest: &str, line: usize, full: &str) {
        let Some(close) = rest.find(')') else {
            self.emit(line, A0_UNKNOWN, format!("unparsable annotation `{full}`"));
            return;
        };
        let id = &rest[..close];
        let after = rest[close + 1..].trim();
        let well_formed = !id.is_empty()
            && id.chars().all(|c| c.is_ascii_lowercase() || c == '-')
            && (after.is_empty() || after.starts_with("--"));
        if !well_formed {
            self.emit(line, A0_UNKNOWN, format!("unparsable annotation `{full}`"));
            return;
        }
        let Some(lint) = allow_lint(id) else {
            self.emit(line, A0_UNKNOWN, format!("unknown allow id `{id}`"));
            return;
        };
        let reason = after.strip_prefix("--").map(str::trim).unwrap_or("");
        if reason.is_empty() {
            self.emit(
                line,
                A0_MISSING_REASON,
                format!("allow({id}) needs a reason: `// analyze: allow({id}) -- <why>`"),
            );
            return;
        }
        self.allows.push(Allow {
            line,
            lint,
            reason: reason.to_string(),
            used: false,
        });
    }

    // ---- L1: alloc-free hot paths ----
    fn l1(&mut self) {
        let mut found: Vec<(usize, &'static str, String)> = Vec::new();
        for hf in &self.hot {
            for i in hf.start..hf.end {
                let t = &self.toks[i];
                if t.kind != Kind::Ident {
                    continue;
                }
                let name = t.text.as_str();
                if ALLOC_METHODS.contains(&name) && self.prev_is(i, ".") && self.next_is(i, "(") {
                    found.push((
                        t.line,
                        L1_ALLOC,
                        format!("`.{name}()` in hot-path fn `{}` (alloc-free contract)", hf.name),
                    ));
                } else if ALLOC_MACROS.contains(&name) && self.next_is(i, "!") {
                    found.push((
                        t.line,
                        L1_ALLOC,
                        format!("`{name}!` in hot-path fn `{}` (alloc-free contract)", hf.name),
                    ));
                } else if ALLOC_PATHS.contains(&name) && self.next_is(i, ":") {
                    found.push((
                        t.line,
                        L1_ALLOC,
                        format!("`{name}::` in hot-path fn `{}` (alloc-free contract)", hf.name),
                    ));
                }
            }
        }
        for (line, lint, msg) in found {
            self.emit(line, lint, msg);
        }
    }

    // ---- L1.obs: hot paths use only the alloc-free observability API ----
    fn l1_obs(&mut self) {
        let mut found: Vec<(usize, &'static str, String)> = Vec::new();
        for hf in &self.hot {
            for i in hf.start..hf.end {
                let t = &self.toks[i];
                if t.kind != Kind::Ident {
                    continue;
                }
                let name = t.text.as_str();
                if OBS_HEAVY_CALLS.contains(&name) && self.next_is(i, "(") {
                    found.push((
                        t.line,
                        L1_OBS,
                        format!(
                            "`{name}(` in hot-path fn `{}` — resolve metric handles outside \
                             the loop; hot paths may only touch the alloc-free recorder API",
                            hf.name
                        ),
                    ));
                } else if OBS_MACROS.contains(&name) && self.next_is(i, "!") {
                    found.push((
                        t.line,
                        L1_OBS,
                        format!(
                            "`{name}!` in hot-path fn `{}` — spans and log lines are \
                             phase-granularity, never per step attempt",
                            hf.name
                        ),
                    ));
                }
            }
        }
        for (line, lint, msg) in found {
            self.emit(line, lint, msg);
        }
    }

    // ---- L2: panic freedom ----
    fn l2(&mut self, index_too: bool) {
        let mut found: Vec<(usize, &'static str, String)> = Vec::new();
        for i in 0..self.toks.len() {
            if self.test_mask[i] {
                continue;
            }
            let t = &self.toks[i];
            let name = t.text.as_str();
            if t.kind == Kind::Ident
                && (name == "unwrap" || name == "expect")
                && self.prev_is(i, ".")
                && self.next_is(i, "(")
            {
                found.push((
                    t.line,
                    L2_PANIC,
                    format!("`.{name}()` outside tests (panic-freedom contract)"),
                ));
            } else if t.kind == Kind::Ident && PANIC_MACROS.contains(&name) && self.next_is(i, "!")
            {
                found.push((
                    t.line,
                    L2_PANIC,
                    format!("`{name}!` outside tests (panic-freedom contract)"),
                ));
            } else if index_too && t.kind == Kind::Punct && t.text == "[" {
                let indexable = self.prev(i).is_some_and(|p| {
                    p.kind == Kind::Ident || (p.kind == Kind::Punct && (p.text == ")" || p.text == "]"))
                });
                if indexable {
                    found.push((
                        t.line,
                        L2_INDEX,
                        "slice indexing outside tests (can panic on bad bounds)".to_string(),
                    ));
                }
            }
        }
        for (line, lint, msg) in found {
            self.emit(line, lint, msg);
        }
    }

    // ---- L4: lock discipline ----
    fn l4(&mut self, order: &LockOrder) {
        let toks = self.toks;
        let mut guards: Vec<Guard> = Vec::new();
        let mut depth = 0i64;
        let mut found: Vec<(usize, &'static str, String)> = Vec::new();
        let mut i = 0usize;
        while i < toks.len() {
            let t = &toks[i];
            if t.kind == Kind::Punct {
                match t.text.as_str() {
                    "{" => depth += 1,
                    "}" => {
                        depth -= 1;
                        guards.retain(|g| g.depth <= depth);
                    }
                    ";" => guards.retain(|g| !g.temp),
                    _ => {}
                }
            }
            let in_wrapper = self.fn_of[i]
                .is_some_and(|idx| order.wrappers.contains(&self.fn_names[idx]));
            if self.test_mask[i] || in_wrapper {
                i += 1;
                continue;
            }
            // drop(name) releases a named guard early.
            if t.kind == Kind::Ident && t.text == "drop" && self.next_is(i, "(") {
                if let Some(name) = toks.get(i + 2).filter(|n| n.kind == Kind::Ident) {
                    guards.retain(|g| g.name.as_deref() != Some(name.text.as_str()));
                }
            }
            let mut acquired: Option<String> = None;
            if t.kind == Kind::Ident
                && (t.text == "lock" || t.text == "try_lock")
                && self.prev_is(i, ".")
                && self.next_is(i, "(")
            {
                acquired = Some(match toks.get(i.wrapping_sub(2)) {
                    Some(r) if i >= 2 && r.kind == Kind::Ident => r.text.clone(),
                    _ => "?".to_string(),
                });
            } else if t.kind == Kind::Ident
                && order.wrappers.contains(&t.text)
                && self.next_is(i, "(")
            {
                // Receiver of a wrapper call: last ident in the arg list.
                let mut d = 0i64;
                let mut j = i + 1;
                let mut last: Option<String> = None;
                while j < toks.len() {
                    let tj = &toks[j];
                    if tj.kind == Kind::Punct && tj.text == "(" {
                        d += 1;
                    } else if tj.kind == Kind::Punct && tj.text == ")" {
                        d -= 1;
                        if d == 0 {
                            break;
                        }
                    } else if tj.kind == Kind::Ident {
                        last = Some(tj.text.clone());
                    }
                    j += 1;
                }
                acquired = Some(last.unwrap_or_else(|| "?".to_string()));
            }
            if let Some(lock) = acquired {
                let rank = match order.rank.get(&lock) {
                    Some(&r) => r,
                    None => {
                        found.push((
                            t.line,
                            L4_UNDECLARED,
                            format!("lock on `{lock}` is not declared in lock_order.txt"),
                        ));
                        -1
                    }
                };
                for g in &guards {
                    if rank >= 0 && g.rank >= 0 && rank <= g.rank {
                        found.push((
                            t.line,
                            L4_ORDER,
                            format!(
                                "lock `{lock}` (rank {rank}) acquired while `{}` (rank {}) may \
                                 be held (declared order violated)",
                                g.lock, g.rank
                            ),
                        ));
                    }
                }
                // Statement-`let` binding => guard lives to end of block;
                // `if let` / `while let` and bare temporaries => to `;`.
                let mut name: Option<String> = None;
                let mut temp = true;
                let mut j = i;
                while j > 0 {
                    j -= 1;
                    let tj = &toks[j];
                    if tj.kind == Kind::Punct && (tj.text == ";" || tj.text == "{" || tj.text == "}")
                    {
                        break;
                    }
                    if tj.kind == Kind::Ident && tj.text == "let" {
                        let cond = j > 0
                            && toks[j - 1].kind == Kind::Ident
                            && (toks[j - 1].text == "if" || toks[j - 1].text == "while");
                        if !cond {
                            let mut x = j + 1;
                            while toks.get(x).is_some_and(|t| t.kind == Kind::Ident && t.text == "mut")
                            {
                                x += 1;
                            }
                            if let Some(b) = toks.get(x).filter(|t| t.kind == Kind::Ident) {
                                name = Some(b.text.clone());
                                temp = false;
                            }
                        }
                        break;
                    }
                }
                guards.push(Guard {
                    rank,
                    lock,
                    name,
                    depth,
                    temp,
                });
                i += 1;
                continue;
            }
            if !guards.is_empty()
                && t.kind == Kind::Ident
                && IO_CALLS.contains(&t.text.as_str())
                && self.prev_is(i, ".")
                && self.next_is(i, "(")
            {
                let held: Vec<&str> = guards.iter().map(|g| g.lock.as_str()).collect();
                found.push((
                    t.line,
                    L4_HELD,
                    format!(
                        "blocking call `.{}()` while lock(s) held: {}",
                        t.text,
                        held.join(", ")
                    ),
                ));
            }
            i += 1;
        }
        for (line, lint, msg) in found {
            self.emit(line, lint, msg);
        }
    }

    // ---- L5: FP determinism ----
    fn l5(&mut self) {
        let mut found: Vec<(usize, &'static str, String)> = Vec::new();
        for i in 0..self.toks.len() {
            if self.test_mask[i] {
                continue;
            }
            let t = &self.toks[i];
            if t.kind != Kind::Ident {
                continue;
            }
            let name = t.text.as_str();
            if name == "HashMap" || name == "HashSet" {
                found.push((
                    t.line,
                    L5_HASH,
                    format!(
                        "`{name}` in a reassociation-sensitive module (iteration order is \
                         nondeterministic; use BTreeMap/BTreeSet)"
                    ),
                ));
            }
            if (name == "sum" || name == "product") && self.prev_is(i, ".") {
                // `.sum::<T>()` with an integer T is order-independent.
                let typed_int = self.next_is(i, ":")
                    && self
                        .toks
                        .get(i + 3)
                        .is_some_and(|t| t.kind == Kind::Punct && t.text == "<")
                    && self
                        .toks
                        .get(i + 4)
                        .is_some_and(|t| t.kind == Kind::Ident && INT_TYPES.contains(&t.text.as_str()));
                if !typed_int {
                    found.push((
                        t.line,
                        L5_SUM,
                        format!(
                            "float-ambiguous `.{name}()` accumulation (spell the accumulator: \
                             explicit loop, or turbofish an integer type)"
                        ),
                    ));
                }
            }
        }
        for (line, lint, msg) in found {
            self.emit(line, lint, msg);
        }
    }

    /// Apply in-source allows: an allow suppresses matching findings on
    /// its own line or the next line, and must suppress at least one.
    fn apply_allows(mut self) -> FileReport {
        let mut kept: Vec<Finding> = Vec::new();
        for f in self.findings.into_iter() {
            if f.lint.starts_with("A0.") {
                kept.push(f);
                continue;
            }
            let mut suppressed = false;
            for a in self.allows.iter_mut() {
                if a.lint == f.lint && (f.line == a.line || f.line == a.line + 1) {
                    suppressed = true;
                    a.used = true;
                    break;
                }
            }
            if !suppressed {
                kept.push(f);
            }
        }
        for a in &self.allows {
            if !a.used {
                kept.push(Finding {
                    file: self.rel.to_string(),
                    line: a.line,
                    lint: A0_STALE_ALLOW,
                    msg: format!("allow for {} suppresses nothing (remove it)", a.lint),
                });
            }
        }
        FileReport {
            findings: kept,
            hot_fns: self.hot.iter().map(|h| h.name.clone()).collect(),
            wire: self.wire,
            allows: self
                .allows
                .into_iter()
                .map(|a| AllowSite {
                    file: self.rel.to_string(),
                    line: a.line,
                    lint: a.lint,
                    reason: a.reason,
                })
                .collect(),
        }
    }
}

fn parse_group(rest: &str) -> Option<String> {
    let close = rest.find(')')?;
    let id = &rest[..close];
    let tail = rest[close + 1..].trim();
    let ok = !id.is_empty()
        && tail.is_empty()
        && id
            .chars()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-');
    if ok {
        Some(id.to_string())
    } else {
        None
    }
}

/// Lint one file.  `rel` is the path relative to `rust/src/` and selects
/// the scope mask; the L3 wire comparison happens later, across files.
pub fn lint_file(rel: &str, src: &str, order: &LockOrder) -> FileReport {
    let toks = crate::lexer::lex(src);
    let mut pass = FilePass::new(rel, &toks);
    let scope = scope_for(rel);
    pass.l1();
    pass.l1_obs();
    if scope.l2 {
        pass.l2(scope.l2_index);
    }
    if scope.l4 {
        pass.l4(order);
    }
    if scope.l5 {
        pass.l5();
    }
    pass.apply_allows()
}
