//! A minimal Rust lexer — just enough token structure for the lint
//! passes, in the same no-dependency spirit as `rust/src/util/json.rs`.
//!
//! The lexer understands exactly what the lints need and nothing more:
//! line/nested-block comments (kept as tokens, since annotations live in
//! them), string/raw-string/byte-string literals (kept with their inner
//! text, since L3 compares wire strings), char-vs-lifetime
//! disambiguation, numbers, identifiers (including `r#raw`), and
//! single-char punctuation.  It does not build an AST; the lint passes
//! recover the little structure they need (attributes, item extents,
//! brace depth, `fn` bodies) from the token stream.
//!
//! Escapes inside string literals are kept verbatim (`\"` stays two
//! chars): the wire strings L3 extracts are plain identifiers-on-the-
//! wire and never contain escapes, so no unescaping pass is needed.  A
//! `\` + newline line-continuation still advances the line counter so
//! diagnostics stay aligned after multi-line format strings.

/// Token class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Num,
    Str,
    Char,
    Lifetime,
    Punct,
    Comment,
}

/// One token: class, text, and the 1-based source line it starts on.
/// Comment tokens carry their trimmed body (doc-comment markers
/// stripped); string tokens carry the raw inner text.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: usize,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// `#*"` at position `i` — the tail of a raw-string opener.
fn raw_opener(b: &[char], mut i: usize) -> bool {
    while i < b.len() && b[i] == '#' {
        i += 1;
    }
    i < b.len() && b[i] == '"'
}

/// `"` at `j` closes a raw string opened with `hashes` hash marks.
fn raw_closer(b: &[char], j: usize, hashes: usize) -> bool {
    if b[j] != '"' {
        return false;
    }
    for k in 0..hashes {
        if j + 1 + k >= b.len() || b[j + 1 + k] != '#' {
            return false;
        }
    }
    true
}

/// Tokenize `src`.  Never fails: unterminated constructs run to EOF.
pub fn lex(src: &str) -> Vec<Tok> {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // Line comment (annotations live here).
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let mut j = i + 2;
            while j < n && b[j] != '\n' {
                j += 1;
            }
            let mut text: String = b[i + 2..j].iter().collect();
            // Doc-comment markers: `///` and `//!`.
            if text.starts_with('/') || text.starts_with('!') {
                text.remove(0);
            }
            toks.push(Tok {
                kind: Kind::Comment,
                text: text.trim().to_string(),
                line,
            });
            i = j;
            continue;
        }
        // Block comment (nested).
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let start = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if b[j] == '/' && j + 1 < n && b[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == '*' && j + 1 < n && b[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            toks.push(Tok {
                kind: Kind::Comment,
                text: b[i..j].iter().collect::<String>().trim().to_string(),
                line: start,
            });
            i = j;
            continue;
        }
        // Raw strings: r"..", r#".."#, br"..", br#".."#.
        let rawish = (c == 'r' && raw_opener(&b, i + 1))
            || (c == 'b' && i + 1 < n && b[i + 1] == 'r' && raw_opener(&b, i + 2));
        if rawish {
            let mut j = i + 1;
            if c == 'b' {
                j += 1;
            }
            let mut hashes = 0usize;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            j += 1; // opening quote
            let body_start = j;
            let start = line;
            while j < n && !raw_closer(&b, j, hashes) {
                if b[j] == '\n' {
                    line += 1;
                }
                j += 1;
            }
            toks.push(Tok {
                kind: Kind::Str,
                text: b[body_start..j.min(n)].iter().collect(),
                line: start,
            });
            i = (j + 1 + hashes).min(n + 1);
            continue;
        }
        // Raw identifier r#foo.
        if c == 'r' && i + 2 < n && b[i + 1] == '#' && is_ident_start(b[i + 2]) {
            let mut j = i + 2;
            while j < n && is_ident_continue(b[j]) {
                j += 1;
            }
            toks.push(Tok {
                kind: Kind::Ident,
                text: b[i + 2..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        // Byte string / byte char: strip the `b` and fall through.
        let mut i2 = i;
        let mut c2 = c;
        if c == 'b' && i + 1 < n && (b[i + 1] == '"' || b[i + 1] == '\'') {
            i2 = i + 1;
            c2 = b[i2];
        }
        if c2 == '"' {
            let start = line;
            let mut j = i2 + 1;
            let mut buf = String::new();
            while j < n {
                if b[j] == '\\' {
                    if j + 1 < n && b[j + 1] == '\n' {
                        line += 1;
                    }
                    buf.push(b[j]);
                    if j + 1 < n {
                        buf.push(b[j + 1]);
                    }
                    j += 2;
                    continue;
                }
                if b[j] == '"' {
                    break;
                }
                if b[j] == '\n' {
                    line += 1;
                }
                buf.push(b[j]);
                j += 1;
            }
            toks.push(Tok {
                kind: Kind::Str,
                text: buf,
                line: start,
            });
            i = j + 1;
            continue;
        }
        if c2 == '\'' {
            let lifetime = i2 + 1 < n
                && is_ident_start(b[i2 + 1])
                && (i2 + 2 >= n || b[i2 + 2] != '\'');
            if lifetime {
                let mut j = i2 + 1;
                while j < n && is_ident_continue(b[j]) {
                    j += 1;
                }
                toks.push(Tok {
                    kind: Kind::Lifetime,
                    text: b[i2 + 1..j].iter().collect(),
                    line,
                });
                i = j;
                continue;
            }
            let mut j = i2 + 1;
            if j < n && b[j] == '\\' {
                j += 2;
            } else {
                j += 1;
            }
            toks.push(Tok {
                kind: Kind::Char,
                text: b[i2..(j + 1).min(n)].iter().collect(),
                line,
            });
            i = j + 1;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n && is_ident_continue(b[j]) {
                j += 1;
            }
            // Fraction: `.` followed by a digit (so `0..n` stays a range).
            if j < n && b[j] == '.' && j + 1 < n && b[j + 1].is_ascii_digit() {
                j += 1;
                while j < n && is_ident_continue(b[j]) {
                    j += 1;
                }
            }
            // Signed exponent: `1e-12`.
            if j < n && (b[j] == '+' || b[j] == '-') && (b[j - 1] == 'e' || b[j - 1] == 'E') {
                j += 1;
                while j < n && is_ident_continue(b[j]) {
                    j += 1;
                }
            }
            toks.push(Tok {
                kind: Kind::Num,
                text: b[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_continue(b[j]) {
                j += 1;
            }
            toks.push(Tok {
                kind: Kind::Ident,
                text: b[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        toks.push(Tok {
            kind: Kind::Punct,
            text: c.to_string(),
            line,
        });
        i += 1;
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn comments_strings_lifetimes() {
        let toks = kinds("// analyze: hot-path\nfn f<'a>(s: &'a str) { let x = \"ab\"; }");
        assert_eq!(toks[0], (Kind::Comment, "analyze: hot-path".to_string()));
        assert!(toks.contains(&(Kind::Lifetime, "a".to_string())));
        assert!(toks.contains(&(Kind::Str, "ab".to_string())));
    }

    #[test]
    fn char_literal_is_not_a_lifetime() {
        let toks = kinds("let c = 'x'; let n = '\\n';");
        assert!(toks.iter().any(|t| t.0 == Kind::Char && t.1 == "'x'"));
        assert!(!toks.iter().any(|t| t.0 == Kind::Lifetime));
    }

    #[test]
    fn raw_and_byte_strings() {
        let toks = kinds("r#\"a \"quoted\" b\"# b\"bytes\" br\"raw\"");
        assert_eq!(toks[0], (Kind::Str, "a \"quoted\" b".to_string()));
        assert_eq!(toks[1], (Kind::Str, "bytes".to_string()));
        assert_eq!(toks[2], (Kind::Str, "raw".to_string()));
    }

    #[test]
    fn numbers_and_ranges() {
        let toks = kinds("1e-12 0..n 3.5 0x1f");
        assert_eq!(toks[0], (Kind::Num, "1e-12".to_string()));
        assert_eq!(toks[1], (Kind::Num, "0".to_string()));
        assert_eq!(toks[2], (Kind::Punct, ".".to_string()));
        assert_eq!(toks[3], (Kind::Punct, ".".to_string()));
        assert_eq!(toks[4], (Kind::Ident, "n".to_string()));
        assert_eq!(toks[5], (Kind::Num, "3.5".to_string()));
        assert_eq!(toks[6], (Kind::Num, "0x1f".to_string()));
    }

    #[test]
    fn line_continuation_keeps_line_numbers() {
        let src = "let s = \"a \\\n  b\";\nlet t = 1;";
        let toks = lex(src);
        let t = toks.iter().find(|t| t.text == "t").unwrap();
        assert_eq!(t.line, 3);
    }

    #[test]
    fn nested_block_comment() {
        let toks = kinds("/* outer /* inner */ tail */ x");
        assert_eq!(toks.len(), 2);
        assert_eq!(toks[1], (Kind::Ident, "x".to_string()));
    }
}
