//! Runtime microbenchmarks (the §Perf L3 profile): per-step overhead
//! decomposition of the hot path — input literal construction, execution,
//! output decode — for the MNIST NODE train artifact at each ladder rung.
use std::time::Instant;

use regnde::runtime::{Engine, Input};
use regnde::util::stats;

fn main() {
    let engine = Engine::new(regnde::default_artifacts_dir()).expect("artifacts");
    let model = engine.manifest.model("mnist_node").unwrap().clone();
    let params = engine.init_params("mnist_node", 0).unwrap();
    let opt = vec![0.0f32; model.opt_state_size];
    let x = vec![0.3f32; 32 * 784];
    let mut y = vec![0.0f32; 32 * 10];
    for i in 0..32 {
        y[i * 10 + i % 10] = 1.0;
    }

    for rung in ["mnist_node_train_b16", "mnist_node_train_b32", "mnist_node_train_b64"] {
        engine.load(rung).unwrap(); // exclude compile from timing
        let reps = 5;
        let mut times = Vec::new();
        for _ in 0..reps {
            let t0 = Instant::now();
            let out = engine
                .run(
                    rung,
                    &[
                        Input::F32(&params),
                        Input::F32(&opt),
                        Input::F32(&x),
                        Input::F32(&y),
                        Input::Scalar(0.1),
                        Input::Scalar(0.0),
                        Input::Scalar(0.0),
                        Input::Scalar(0.0),
                        Input::Scalar(1.0),
                    ],
                )
                .unwrap();
            std::hint::black_box(&out);
            times.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        println!(
            "{rung:<24} {:>8.1} ms/step  (min {:>7.1}, max {:>7.1}, n={reps})",
            stats::mean(&times),
            stats::min(&times),
            stats::max(&times)
        );
    }
    println!("\nbudget rung wall-clock should scale ~linearly with budget — the");
    println!("gap the budget-ladder router converts into training-time savings.");

    // predict path: NFE-proportional wall clock
    engine.load("mnist_node_predict").unwrap();
    let mut times = Vec::new();
    for _ in 0..5 {
        let t0 = Instant::now();
        let out = engine
            .run(
                "mnist_node_predict",
                &[Input::F32(&params), Input::F32(&x), Input::F32(&y)],
            )
            .unwrap();
        std::hint::black_box(&out);
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    println!(
        "\nmnist_node_predict        {:>8.1} ms (early-exiting while loop)",
        stats::mean(&times)
    );
}
