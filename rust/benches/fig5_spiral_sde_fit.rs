//! Regenerates paper Figure 5: spiral Neural SDE fit — predicted ensemble
//! mean/variance band vs the ground-truth data moments per save point.
use regnde::bench::{run_grid, BenchConfig};
use regnde::coordinator::experiments::spiral_nsde;
use regnde::coordinator::Method;

fn main() {
    let cfg = BenchConfig::from_env(2, 15);
    let methods = ["vanilla", "ernsde"].map(|m| Method::parse(m).unwrap());
    let grid = run_grid("spiral-nsde", &methods, &cfg).expect("bench failed");

    let (_, mu, var, _) = spiral_nsde::ground_truth(0);
    println!("Figure 5 — data moments vs fitted-model GMM loss\n");
    println!("ground-truth moment band (native Rust SDE ensemble):");
    for k in (0..30).step_by(5) {
        println!(
            "  t[{k:>2}] mu=({:>7.4},{:>7.4})  sd=({:.4},{:.4})",
            mu[k * 2],
            mu[k * 2 + 1],
            var[k * 2].sqrt(),
            var[k * 2 + 1].sqrt()
        );
    }
    println!();
    for m in &grid {
        let gmm = m.summary(|r| r.final_test_loss);
        let nfe = m.summary(|r| r.predict_nfe);
        println!(
            "{:<14} GMM loss {:.4} ± {:.4} | NFE {:.1} ± {:.1}",
            m.method.label(true),
            gmm.mean,
            gmm.std,
            nfe.mean,
            nfe.std
        );
    }
    println!("\npaper shape: regularization keeps the moment fit with fewer NFE");
}
