//! Ablation A2: the paper's two error-regularizer variants —
//! R_E = sum E_j |h_j| (Eq. 9) vs R_E = sum E_j^2 (§4.1.2 note) — measured
//! on the same solves, plus budget-ladder router telemetry under each.
use regnde::bench::{run_grid, BenchConfig};
use regnde::coordinator::Method;
use regnde::solvers::{problems, solve_ensemble, EnsembleOptions, SolveOptions};
use regnde::util::tablefmt::Table;

fn main() {
    // (a) statically: how the two accumulators scale with tolerance,
    // averaged over an 8-IC spiral ensemble (solvers::ensemble).
    let z0s: Vec<Vec<f64>> = (0..8)
        .map(|i| {
            let th = std::f64::consts::TAU * i as f64 / 8.0;
            vec![2.0 * th.cos(), 2.0 * th.sin()]
        })
        .collect();
    let eopts = EnsembleOptions::default();
    let mut t = Table::new(
        "Ablation — R_E variants on the cubic spiral (native Tsit5, mean/IC)",
        &["rtol=atol", "sum E|h| (Eq.9)", "sum E^2 (variant)"],
    );
    for tol in [1e-3, 1e-5, 1e-7] {
        let opts = SolveOptions::new().with_tolerance(tol);
        let outs: Vec<_> = solve_ensemble(&problems::spiral_ode, &z0s, 0.0, 1.5, &opts, &eopts)
            .into_iter()
            .map(|o| o.expect("ablation solve failed"))
            .collect();
        let n = outs.len() as f64;
        t.row(vec![
            format!("{tol:.0e}"),
            format!("{:.3e}", outs.iter().map(|o| o.stats.r_e).sum::<f64>() / n),
            format!("{:.3e}", outs.iter().map(|o| o.stats.r_e2).sum::<f64>() / n),
        ]);
    }
    println!("{}", t.render());

    // (b) dynamically: router telemetry for vanilla vs ernode training
    let cfg = BenchConfig::from_env(2, 6);
    let methods = ["vanilla", "ernode"].map(|m| Method::parse(m).unwrap());
    let grid = run_grid("mnist-node", &methods, &cfg).expect("bench failed");
    println!("budget-ladder telemetry (escalations / descents over the run):");
    for m in &grid {
        let esc = m.summary(|r| r.escalations as f64).mean;
        let desc = m.summary(|r| r.descents as f64).mean;
        println!(
            "  {:<14} escalations {esc:.1}  descents {desc:.1}",
            m.method.label(false)
        );
    }
}
