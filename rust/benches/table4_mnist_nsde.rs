//! Regenerates paper Table 4: MNIST classification with a Neural SDE —
//! Vanilla / SRNSDE / ERNSDE with accuracy, times and NFE.
use regnde::bench::{render_table, run_grid, BenchConfig};
use regnde::coordinator::Method;

fn main() {
    let cfg = BenchConfig::from_env(2, 8);
    let grid = run_grid("mnist-nsde", &Method::table_grid_sde(), &cfg)
        .expect("bench failed — run `make artifacts` first");
    println!(
        "{}",
        render_table(
            "Table 4 — MNIST Image Classification using Neural SDE (testbed scale)",
            &grid,
            true,
            true,
        )
    );
    println!("paper reference: ERNSDE 1.51x train / 2.08x predict speedup, NFE 411 -> 185");
}
