//! Regenerates paper Figure 6: NFE and training error vs epoch for the
//! MNIST Neural SDE (ERNSDE bounds NFE below the unregularized run).
use regnde::bench::{render_series, run_grid, BenchConfig};
use regnde::coordinator::Method;

fn main() {
    let cfg = BenchConfig::from_env(4, 6);
    let grid = run_grid("mnist-nsde", &Method::table_grid_sde(), &cfg)
        .expect("bench failed");
    println!(
        "{}",
        render_series(
            "Figure 6 — MNIST NSDE: NFE and train accuracy vs epoch",
            &grid,
            true,
        )
    );
    println!("paper shape: ERNSDE holds NFE < 300 vs ~400 unregularized");
}
