//! Regenerates paper Table 3: fitting the spiral diagonal-noise SDE with a
//! Neural SDE (GMM moment loss) — Vanilla / SRNSDE / ERNSDE.
use regnde::bench::{render_table, run_grid, BenchConfig};
use regnde::coordinator::Method;

fn main() {
    let cfg = BenchConfig::from_env(2, 12);
    let grid = run_grid("spiral-nsde", &Method::table_grid_sde(), &cfg)
        .expect("bench failed — run `make artifacts` first");
    println!(
        "{}",
        render_table(
            "Table 3 — Spiral SDE (GMM moment loss; testbed scale)",
            &grid,
            true,
            false,
        )
    );
    println!("paper reference: SRNSDE 1.08x train / 1.04x predict; NFE 529 -> 502");
}
