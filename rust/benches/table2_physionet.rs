//! Regenerates paper Table 2: Physionet time-series interpolation with the
//! Latent ODE — method grid with loss, train/predict time and NFE.
use regnde::bench::{render_table, run_grid, BenchConfig};
use regnde::coordinator::Method;

fn main() {
    let cfg = BenchConfig::from_env(3, 6);
    let grid = run_grid("latent-ode", &Method::table_grid_ode(), &cfg)
        .expect("bench failed — run `make artifacts` first");
    println!(
        "{}",
        render_table(
            "Table 2 — Physionet Time Series Interpolation (testbed scale; metric = masked MSE)",
            &grid,
            false,
            false,
        )
    );
    println!("paper reference: SRNODE 2.0x train / 2.6x predict speedup, NFE 733 -> 273; TayNODE trains 7x SLOWER");
}
