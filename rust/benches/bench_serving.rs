//! Serving benchmark — the end-to-end proof that regularized training
//! pays off at serve time (ISSUE 5 acceptance).
//!
//! Trains a spiral-NODE **vanilla** and an **ernode** model from the
//! same seed, exports both as serving checkpoints, hosts them behind the
//! micro-batching TCP server on loopback, and fires concurrent predict
//! requests at each.  Asserts:
//!
//!  * a served single request is **bit-identical** to the in-process
//!    `Backend::predict` on the same input,
//!  * every request under load succeeds with NFE reported per response,
//!  * requests coalesce (mean batch > 1 under concurrency),
//!  * the ernode model's mean NFE/request is no worse than vanilla's —
//!    fewer solver steps per batch is exactly what turns into more
//!    requests per core.
//!
//! Emits `BENCH_serving.json` at the repo root (`bench_serving/v2`,
//! schema in DESIGN.md §Serving): per-model throughput (req/s), exact
//! p50/p99/p999 client latency plus the server-side percentiles
//! reconstructed from the registry's latency histogram
//! (DESIGN.md §Observability), mean batch size and mean NFE/request.
//!
//! Scale knobs (env):
//!   REGNDE_BENCH_EPOCHS       training epochs per model   (default 3)
//!   REGNDE_BENCH_ITERS        optimizer steps per epoch   (default 25)
//!   REGNDE_BENCH_REQUESTS     predict requests per model  (default 256)
//!   REGNDE_BENCH_CONCURRENCY  client connections          (default 16)

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use regnde::coordinator::experiments::{self, TrainOpts};
use regnde::coordinator::Method;
use regnde::obs::metrics;
use regnde::runtime::{Backend, NativeBackend, TrainData};
use regnde::serve::{
    BatchPolicy, Batcher, Checkpoint, Client, Registry, Request, Response, Server, ServerOpts,
};
use regnde::util::cli::env_usize;
use regnde::util::json::{obj, Json};
use regnde::util::tablefmt::Table;
use regnde::util::threadpool::ThreadPool;

struct LoadResult {
    throughput_rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    p999_ms: f64,
    /// Server-side percentiles derived from the registry's
    /// `regnde_serve_latency_seconds{model}` histogram (what a scrape
    /// would reconstruct) — bucket-interpolated, so approximate, but
    /// measured where the solve ran rather than across the loopback
    /// round trip.
    hist_p50_ms: f64,
    hist_p99_ms: f64,
    hist_p999_ms: f64,
    mean_batch: f64,
    mean_nfe: f64,
}

/// Train one method, export its checkpoint, return the trained params.
fn train_and_export(
    be: &NativeBackend,
    method: &str,
    registry: &Registry,
    id: &str,
    epochs: usize,
    iters: usize,
) -> Vec<f32> {
    let opts = TrainOpts {
        epochs,
        iters_per_epoch: iters,
        seed: 0,
        verbose: false,
    };
    let method = Method::parse(method).expect("method");
    let run = experiments::run_by_name(be, "spiral-node", method, opts).expect("train run");
    let state = be
        .export_state("spiral_node", &run.final_params)
        .expect("export");
    let ts = experiments::serving_grid("spiral-node");
    let ckpt = Checkpoint::new(state, "spiral-node", run.method.clone(), ts);
    registry.insert(id, ckpt).expect("register");
    run.final_params
}

/// Fire `requests` predictions across `concurrency` persistent client
/// connections and collect latency/NFE/batch statistics.
fn drive_load(addr: &str, model: &str, requests: usize, concurrency: usize) -> LoadResult {
    let next = AtomicUsize::new(0);
    let t0 = Instant::now();
    let per_lane: Vec<Vec<(u64, u64, usize)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..concurrency)
            .map(|lane| {
                let next = &next;
                scope.spawn(move || {
                    let mut client = Client::connect(addr).expect("connect");
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::SeqCst);
                        if i >= requests {
                            return out;
                        }
                        let u0 = vec![2.0 - 0.001 * (i % 32) as f32, 0.001 * lane as f32];
                        let req = Request::Predict {
                            model: model.to_string(),
                            u0,
                            budget: None,
                            deadline_ms: None,
                        };
                        let t = Instant::now();
                        let resp = client.request(&req).expect("request");
                        let micros = t.elapsed().as_micros() as u64;
                        match resp {
                            Response::Predict { nfe, batch, .. } => {
                                assert!(nfe > 0, "NFE must be reported per response");
                                out.push((micros, nfe, batch));
                            }
                            other => panic!("request {i} failed: {other:?}"),
                        }
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed().as_secs_f64().max(1e-9);

    let mut lat: Vec<u64> = Vec::with_capacity(requests);
    let mut nfe_sum = 0.0;
    let mut batch_sum = 0.0;
    for (micros, nfe, batch) in per_lane.into_iter().flatten() {
        lat.push(micros);
        nfe_sum += nfe as f64;
        batch_sum += batch as f64;
    }
    assert_eq!(lat.len(), requests, "every request must be answered");
    lat.sort_unstable();
    let pct = |p: f64| lat[((lat.len() - 1) as f64 * p) as usize] as f64 / 1000.0;
    // The server runs in this process, so its per-model latency
    // histogram is readable straight from the global registry.
    let hist = metrics::registry().histogram(
        &metrics::labeled("regnde_serve_latency_seconds", "model", model),
        &metrics::LATENCY_BUCKETS,
    );
    LoadResult {
        throughput_rps: requests as f64 / wall,
        p50_ms: pct(0.50),
        p99_ms: pct(0.99),
        p999_ms: pct(0.999),
        hist_p50_ms: hist.quantile(0.50) * 1000.0,
        hist_p99_ms: hist.quantile(0.99) * 1000.0,
        hist_p999_ms: hist.quantile(0.999) * 1000.0,
        mean_batch: batch_sum / requests as f64,
        mean_nfe: nfe_sum / requests as f64,
    }
}

fn result_json(r: &LoadResult) -> Json {
    obj([
        ("throughput_rps", Json::from(r.throughput_rps)),
        ("p50_ms", Json::from(r.p50_ms)),
        ("p99_ms", Json::from(r.p99_ms)),
        ("p999_ms", Json::from(r.p999_ms)),
        (
            "registry_histogram",
            obj([
                ("p50_ms", Json::from(r.hist_p50_ms)),
                ("p99_ms", Json::from(r.hist_p99_ms)),
                ("p999_ms", Json::from(r.hist_p999_ms)),
            ]),
        ),
        ("mean_batch", Json::from(r.mean_batch)),
        ("mean_nfe_per_request", Json::from(r.mean_nfe)),
    ])
}

fn main() {
    let epochs = env_usize("REGNDE_BENCH_EPOCHS", 3).max(1);
    let iters = env_usize("REGNDE_BENCH_ITERS", 25).max(1);
    let requests = env_usize("REGNDE_BENCH_REQUESTS", 256).max(8);
    let concurrency = env_usize("REGNDE_BENCH_CONCURRENCY", 16).clamp(2, requests);

    // ---- train both models and build the registry ---------------------
    let be = NativeBackend::new();
    let registry = Arc::new(Registry::in_memory());
    let vanilla_params =
        train_and_export(&be, "vanilla", &registry, "spiral-vanilla", epochs, iters);
    let _ = train_and_export(&be, "ernode", &registry, "spiral-ernode", epochs, iters);

    // ---- host them on loopback ----------------------------------------
    let pool = Arc::new(ThreadPool::new(4));
    let policy = BatchPolicy {
        max_batch: 16,
        max_wait: Duration::from_micros(5000),
        ..Default::default()
    };
    let batcher = Arc::new(Batcher::new(Arc::clone(&registry), pool, policy));
    let opts = ServerOpts {
        nfe_quota: u64::MAX,
        ..Default::default()
    };
    let (addr, _server) =
        Server::spawn(Arc::clone(&registry), batcher, opts, "127.0.0.1:0").expect("spawn server");
    let addr = addr.to_string();

    // ---- bit-exactness: served response == in-process predict ---------
    {
        let mut client = Client::connect(&addr).expect("connect");
        let resp = client
            .request(&Request::Predict {
                model: "spiral-vanilla".into(),
                u0: vec![2.0, 0.0],
                budget: None,
                deadline_ms: None,
            })
            .expect("predict");
        let traj = match resp {
            Response::Predict { traj, .. } => traj,
            other => panic!("predict failed: {other:?}"),
        };
        let (data, ts) = experiments::spiral_node::ground_truth();
        let payload = TrainData::Trajectory { data: &data, ts: &ts };
        let (pred, _) = be
            .predict("spiral_node", &vanilla_params, &payload, 0)
            .expect("in-process predict");
        assert_eq!(pred.len(), traj.len());
        for (a, b) in pred.iter().zip(&traj) {
            assert_eq!(a.to_bits(), b.to_bits(), "served bits != in-process bits");
        }
        println!("bit-exactness: served == in-process predict ({} floats)", traj.len());
    }

    // ---- measure both models under identical load ---------------------
    let vanilla = drive_load(&addr, "spiral-vanilla", requests, concurrency);
    let ernode = drive_load(&addr, "spiral-ernode", requests, concurrency);

    assert!(
        vanilla.mean_batch > 1.0 || ernode.mean_batch > 1.0,
        "concurrent load must coalesce somewhere (vanilla {:.2}, ernode {:.2})",
        vanilla.mean_batch,
        ernode.mean_batch
    );
    // The paper's serving claim: the regularized model spends no more
    // solver work per request (same gate CI's --check-nfe applies to
    // training NFE).
    assert!(
        ernode.mean_nfe <= vanilla.mean_nfe * 1.05,
        "ernode mean NFE/request {} must not exceed vanilla's {}",
        ernode.mean_nfe,
        vanilla.mean_nfe
    );

    let mut table = Table::new(
        "Serving — micro-batched spiral-NODE over loopback TCP",
        &[
            "model",
            "req/s",
            "p50 ms",
            "p99 ms",
            "p999 ms",
            "hist p99 ms",
            "mean batch",
            "mean NFE/req",
        ],
    );
    for (name, r) in [("vanilla", &vanilla), ("ernode", &ernode)] {
        table.row(vec![
            name.to_string(),
            format!("{:.1}", r.throughput_rps),
            format!("{:.2}", r.p50_ms),
            format!("{:.2}", r.p99_ms),
            format!("{:.2}", r.p999_ms),
            format!("{:.2}", r.hist_p99_ms),
            format!("{:.2}", r.mean_batch),
            format!("{:.1}", r.mean_nfe),
        ]);
    }
    println!("{}", table.render());
    println!(
        "NFE ratio vanilla/ernode = {:.3}x ({} requests x {} lanes per model)",
        vanilla.mean_nfe / ernode.mean_nfe.max(1e-9),
        requests,
        concurrency
    );

    // ---- shed accounting (DESIGN.md §Robustness) ----------------------
    // The server's stats op reports how many requests backpressure turned
    // away (admission queue, deadlines, connection cap, draining).  Under
    // this benchmark's clean load the rate should be 0; the chaos smoke
    // job reads the same field after injecting faults.
    let (shed_total, served_total) = {
        let mut client = Client::connect(&addr).expect("connect for stats");
        match client.request(&Request::Stats).expect("stats") {
            Response::Stats { shed, requests, .. } => (shed, requests),
            other => panic!("stats failed: {other:?}"),
        }
    };
    let shed_rate = shed_total as f64 / (shed_total + served_total).max(1) as f64;
    println!(
        "shed: {shed_total} of {} arrivals ({:.4} rate)",
        shed_total + served_total,
        shed_rate
    );

    // ---- emit BENCH_serving.json at the repo root ---------------------
    let nfe_ratio = vanilla.mean_nfe / ernode.mean_nfe.max(1e-9);
    let report = obj([
        ("schema", Json::from("bench_serving/v2")),
        ("experiment", Json::from("spiral-node")),
        ("vanilla", result_json(&vanilla)),
        ("ernode", result_json(&ernode)),
        ("nfe_ratio_vanilla_over_ernode", Json::from(nfe_ratio)),
        ("shed", Json::from(shed_total as usize)),
        ("shed_rate", Json::from(shed_rate)),
        (
            "meta",
            obj([
                ("epochs", Json::from(epochs)),
                ("iters_per_epoch", Json::from(iters)),
                ("requests", Json::from(requests)),
                ("concurrency", Json::from(concurrency)),
                ("max_batch", Json::from(policy.max_batch)),
                ("max_wait_us", Json::from(policy.max_wait.as_micros() as usize)),
                (
                    "available_parallelism",
                    Json::from(
                        std::thread::available_parallelism()
                            .map(|n| n.get())
                            .unwrap_or(1),
                    ),
                ),
            ]),
        ),
    ]);
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_serving.json");
    std::fs::write(&path, report.to_string_pretty()).expect("write bench report");
    println!("wrote {}", path.display());
}
