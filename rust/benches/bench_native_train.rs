//! Native training perf smoke: a short spiral-NODE `srnode+ernode` run
//! through the discrete-adjoint backend — forward tape + backward pass
//! differentiating `data_loss + coef_e·R_E + coef_s·R_S` — timed end to
//! end, with the paper-claim invariants asserted inline.  The run is
//! executed twice — once with `kernels::set_scalar_fallback(true)` (the
//! retained per-row scalar path) and once on the vectorized batched
//! kernels — so each report carries the epoch-wall-clock before/after of
//! the kernel hot path.
//!
//! Emits `BENCH_native_train.json` at the repo root (schema documented in
//! rust/DESIGN.md §Perf) so the native-training perf trajectory is
//! tracked PR over PR alongside `BENCH_solver_core.json`.
//!
//! Scale knobs (env):
//!   REGNDE_BENCH_EPOCHS  training epochs            (default 3)
//!   REGNDE_BENCH_ITERS   optimizer steps per epoch  (default 25)

use regnde::coordinator::experiments::{self, TrainOpts};
use regnde::coordinator::Method;
use regnde::models::kernels;
use regnde::runtime::NativeBackend;
use regnde::util::cli::env_usize;
use regnde::util::json::{obj, Json};
use regnde::util::tablefmt::Table;

fn main() {
    let epochs = env_usize("REGNDE_BENCH_EPOCHS", 3).max(1);
    let iters = env_usize("REGNDE_BENCH_ITERS", 25).max(1);
    let method = Method::parse("srnode+ernode").expect("method");
    let opts = TrainOpts {
        epochs,
        iters_per_epoch: iters,
        seed: 0,
        verbose: false,
    };

    let be = NativeBackend::new();
    // Ablation leg first: identical run on the per-row scalar path.
    kernels::set_scalar_fallback(true);
    let run_scalar =
        experiments::run_by_name(&be, "spiral-node", method, opts).expect("train run (scalar)");
    kernels::set_scalar_fallback(false);
    let run = experiments::run_by_name(&be, "spiral-node", method, opts).expect("train run");

    let first = run.epochs.first().expect("epochs recorded");
    let last = run.epochs.last().expect("epochs recorded");
    let total_steps = (epochs * iters) as f64;
    let steps_per_sec = total_steps / run.train_time_s.max(1e-9);
    let epoch_time_scalar_s = run_scalar.train_time_s / epochs as f64;
    let epoch_time_kernel_s = run.train_time_s / epochs as f64;
    let kernel_speedup = epoch_time_scalar_s / epoch_time_kernel_s.max(1e-9);

    // The invariants the CI smoke rides on: both regularizers accumulate,
    // the stiffness gradient is part of the update (PR 3), and the short
    // run still optimizes.
    assert!(last.r_e > 0.0, "R_E must accumulate (got {})", last.r_e);
    assert!(last.r_s > 0.0, "R_S must accumulate (got {})", last.r_s);
    assert!(
        last.loss.is_finite() && last.loss < first.loss,
        "training must decrease the loss ({} -> {})",
        first.loss,
        last.loss
    );

    let mut table = Table::new(
        "Native training — spiral NODE, SRNODE + ERNODE (discrete adjoint)",
        &[
            "epochs x iters",
            "steps/sec",
            "epoch scalar (s)",
            "epoch kernel (s)",
            "speedup",
            "final loss",
            "r_e",
            "r_s",
        ],
    );
    table.row(vec![
        format!("{epochs} x {iters}"),
        format!("{steps_per_sec:.2}"),
        format!("{epoch_time_scalar_s:.3}"),
        format!("{epoch_time_kernel_s:.3}"),
        format!("{kernel_speedup:.2}x"),
        format!("{:.5}", last.loss),
        format!("{:.3e}", last.r_e),
        format!("{:.3e}", last.r_s),
    ]);
    println!("{}", table.render());

    let report = obj([
        ("schema", Json::from("bench_native_train/v2")),
        ("experiment", Json::from(run.experiment.as_str())),
        ("method", Json::from(run.method.as_str())),
        ("epochs", Json::from(epochs)),
        ("iters_per_epoch", Json::from(iters)),
        ("train_time_s", Json::from(run.train_time_s)),
        ("steps_per_sec", Json::from(steps_per_sec)),
        ("epoch_time_scalar_s", Json::from(epoch_time_scalar_s)),
        ("epoch_time_kernel_s", Json::from(epoch_time_kernel_s)),
        ("kernel_speedup", Json::from(kernel_speedup)),
        ("loss_first_epoch", Json::from(first.loss)),
        ("loss_final_epoch", Json::from(last.loss)),
        ("nfe_final_epoch", Json::from(last.nfe)),
        ("r_e_final_epoch", Json::from(last.r_e)),
        ("r_s_final_epoch", Json::from(last.r_s)),
        ("predict_nfe", Json::from(run.predict_nfe)),
        ("predict_time_s", Json::from(run.predict_time_s)),
        ("escalations", Json::from(run.escalations as usize)),
        (
            "meta",
            obj([(
                "available_parallelism",
                Json::from(
                    std::thread::available_parallelism()
                        .map(|n| n.get())
                        .unwrap_or(1),
                ),
            )]),
        ),
    ]);
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_native_train.json");
    std::fs::write(&path, report.to_string_pretty()).expect("write bench report");
    println!("wrote {}", path.display());
}
