//! Distributed-training benchmark (ISSUE 9 acceptance).
//!
//! Measures epoch wall-clock for data-parallel `grad_step` sharding over
//! loopback `Worker` processes at 1, 2 and 4 workers (shards == workers),
//! against the plain single-process native backend, and reports scaling
//! efficiency `t_1 / (n * t_n)`.  Loopback workers share this machine's
//! cores, so efficiency is an upper-bound sanity signal (the wire +
//! reduction overhead), not a cluster measurement.
//!
//! Also asserts the determinism guarantee under timing noise: two
//! back-to-back distributed epochs from the same state produce
//! bit-identical parameters.
//!
//! Emits `BENCH_distributed.json` at the repo root (schema in DESIGN.md
//! §Perf).
//!
//! Scale knobs (env):
//!   REGNDE_BENCH_EPOCHS  measured epochs per config   (default 2)
//!   REGNDE_BENCH_ITERS   optimizer steps per epoch    (default 8)
//!   REGNDE_BENCH_BATCH   classification batch rows    (default 64)

use std::sync::Arc;
use std::time::Instant;

use regnde::dist::{DistBackend, RemoteOpts, Worker, WorkerHandle, WorkerOpts};
use regnde::runtime::{Backend, NativeBackend, StepCoefs, TrainData, TrainState};
use regnde::util::cli::env_usize;
use regnde::util::json::{obj, Json};
use regnde::util::rng::Rng;
use regnde::util::tablefmt::Table;

const MODEL: &str = "mnist_node";
const IMG_DIM: usize = 784;
const CLASSES: usize = 10;

fn classify_batch(b: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0f32; b * IMG_DIM];
    rng.fill_normal(&mut x, 0.5);
    let mut y = vec![0.0f32; b * CLASSES];
    for row in 0..b {
        y[row * CLASSES + rng.below(CLASSES)] = 1.0;
    }
    (x, y)
}

struct ConfigResult {
    workers: usize,
    epoch_wall_s: f64,
    final_loss: f64,
}

/// Run `epochs` epochs of `iters` steps on `backend` from a fresh state;
/// returns mean epoch wall-clock and the last step's loss.
fn run_epochs(
    backend: &dyn Backend,
    x: &[f32],
    y: &[f32],
    epochs: usize,
    iters: usize,
) -> (f64, f64, Vec<f32>) {
    let info = backend.model(MODEL).expect("model info");
    let mut state = TrainState {
        params: backend.init_params(MODEL, 11).expect("init"),
        opt_state: vec![0.0; info.opt_state_size],
        iter: 0,
    };
    let data = TrainData::Classify { x, y };
    let mut last_loss = f64::NAN;
    let t0 = Instant::now();
    for epoch in 0..epochs {
        for i in 0..iters {
            let coefs = StepCoefs {
                lr: 0.05,
                seed: (epoch * iters + i) as u32,
                ..Default::default()
            };
            let m = backend
                .train_step(MODEL, false, 0, &mut state, &data, &coefs)
                .expect("train step");
            last_loss = m.loss;
        }
    }
    let wall = t0.elapsed().as_secs_f64() / epochs.max(1) as f64;
    (wall, last_loss, state.params)
}

fn main() {
    let epochs = env_usize("REGNDE_BENCH_EPOCHS", 2).max(1);
    let iters = env_usize("REGNDE_BENCH_ITERS", 8).max(1);
    let batch = env_usize("REGNDE_BENCH_BATCH", 64).max(8);
    let (x, y) = classify_batch(batch, 0xBE7C);

    // ---- single-process baseline (no sharding at all) -----------------
    let plain = NativeBackend::new();
    let (t_plain, plain_loss, _) = run_epochs(&plain, &x, &y, epochs, iters);
    assert!(plain_loss.is_finite(), "baseline diverged");

    // ---- 1 / 2 / 4 loopback workers, shards == workers ----------------
    let mut results: Vec<ConfigResult> = Vec::new();
    for n in [1usize, 2, 4] {
        let handles: Vec<WorkerHandle> = (0..n)
            .map(|_| {
                Worker::spawn(
                    Arc::new(NativeBackend::new()),
                    WorkerOpts::default(),
                    "127.0.0.1:0",
                )
                .expect("spawn worker")
            })
            .collect();
        let addrs: Vec<String> = handles.iter().map(|h| h.addr.to_string()).collect();
        let backend = DistBackend::remote(NativeBackend::new(), &addrs, Some(n), RemoteOpts::default())
            .expect("remote backend");

        // Warm one step (connection establishment) outside the clock.
        let (_, _, params_a) = run_epochs(&backend, &x, &y, 1, 1);
        let (wall, loss, _) = run_epochs(&backend, &x, &y, epochs, iters);
        assert!(loss.is_finite(), "{n}-worker config diverged");

        // Determinism under timing noise: replay the warmup epoch.
        let (_, _, params_b) = run_epochs(&backend, &x, &y, 1, 1);
        assert_eq!(params_a.len(), params_b.len());
        for (i, (a, b)) in params_a.iter().zip(&params_b).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{n}-worker replay drifted at param {i}"
            );
        }

        results.push(ConfigResult {
            workers: n,
            epoch_wall_s: wall,
            final_loss: loss,
        });
        for h in handles {
            h.kill();
        }
    }

    let t1 = results
        .first()
        .map(|r| r.epoch_wall_s)
        .unwrap_or(f64::NAN);

    // ---- report -------------------------------------------------------
    let mut table = Table::new(
        "Distributed — data-parallel grad_step over loopback workers",
        &["config", "epoch wall s", "speedup vs 1w", "efficiency"],
    );
    table.row(vec![
        "single-process".into(),
        format!("{t_plain:.3}"),
        "-".into(),
        "-".into(),
    ]);
    for r in &results {
        let speedup = t1 / r.epoch_wall_s.max(1e-9);
        let eff = speedup / r.workers as f64;
        table.row(vec![
            format!("{} worker(s)", r.workers),
            format!("{:.3}", r.epoch_wall_s),
            format!("{speedup:.2}x"),
            format!("{eff:.2}"),
        ]);
    }
    println!("{}", table.render());

    // ---- emit BENCH_distributed.json at the repo root -----------------
    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            let speedup = t1 / r.epoch_wall_s.max(1e-9);
            obj([
                ("workers", Json::from(r.workers)),
                ("shards", Json::from(r.workers)),
                ("epoch_wall_s", Json::from(r.epoch_wall_s)),
                ("speedup_vs_1worker", Json::from(speedup)),
                ("scaling_efficiency", Json::from(speedup / r.workers as f64)),
                ("final_loss", Json::from(r.final_loss)),
            ])
        })
        .collect();
    let report = obj([
        ("schema", Json::from("bench_distributed/v1")),
        ("model", Json::from(MODEL)),
        ("single_process_epoch_wall_s", Json::from(t_plain)),
        ("configs", Json::Arr(rows)),
        (
            "meta",
            obj([
                ("epochs", Json::from(epochs)),
                ("iters_per_epoch", Json::from(iters)),
                ("batch_rows", Json::from(batch)),
                (
                    "available_parallelism",
                    Json::from(
                        std::thread::available_parallelism()
                            .map(|n| n.get())
                            .unwrap_or(1),
                    ),
                ),
            ]),
        ),
    ]);
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_distributed.json");
    std::fs::write(&path, report.to_string_pretty()).expect("write bench report");
    println!("wrote {}", path.display());
}
