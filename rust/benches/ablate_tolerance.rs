//! Ablation A1: tolerance sweep on the native solver — how NFE, the error
//! regularizer R_E and the stiffness accumulator scale with rtol/atol.
//! (The paper fixes tol = 1.4e-8; DESIGN.md §4 documents our looser
//! default, and this bench quantifies the trade.)
//!
//! Each tolerance is measured over an ensemble of initial conditions on
//! the cubic-spiral ring via `solvers::ensemble`, so the reported
//! accumulators are averages rather than a single trajectory's.
use regnde::solvers::{problems, solve_ensemble, EnsembleOptions, SolveOptions};
use regnde::util::tablefmt::Table;

fn main() {
    // Initial conditions spread over the r=2 ring (the Figure-2 regime).
    let z0s: Vec<Vec<f64>> = (0..8)
        .map(|i| {
            let th = std::f64::consts::TAU * i as f64 / 8.0;
            vec![2.0 * th.cos(), 2.0 * th.sin()]
        })
        .collect();
    let eopts = EnsembleOptions::default();

    let mut t = Table::new(
        "Ablation — tolerance sweep (native Tsit5, 8-IC spiral ensemble, mean/IC)",
        &["rtol=atol", "NFE", "accepted", "rejected", "R_E", "R_S/step"],
    );
    for tol in [1e-2, 1e-3, 1e-4, 1e-5, 1e-6, 1e-7, 1e-8] {
        let opts = SolveOptions::new().with_tolerance(tol);
        let outs: Vec<regnde::solvers::SolveOutcome> =
            solve_ensemble(&problems::spiral_ode, &z0s, 0.0, 1.5, &opts, &eopts)
                .into_iter()
                .map(|o| o.expect("ablation solve failed"))
                .collect();
        let n = outs.len() as f64;
        let mean = |f: &dyn Fn(&regnde::solvers::SolveOutcome) -> f64| -> f64 {
            outs.iter().map(|o| f(o)).sum::<f64>() / n
        };
        t.row(vec![
            format!("{tol:.0e}"),
            format!("{:.1}", mean(&|o| o.stats.nfe as f64)),
            format!("{:.1}", mean(&|o| o.stats.naccept as f64)),
            format!("{:.1}", mean(&|o| o.stats.nreject as f64)),
            format!("{:.3e}", mean(&|o| o.stats.r_e)),
            format!("{:.2}", mean(&|o| o.stats.r_s / o.stats.naccept as f64)),
        ]);
    }
    println!("{}", t.render());
    println!("expected shape: NFE grows ~tol^(-1/5) (5th-order method); R_E shrinks with tol");
}
