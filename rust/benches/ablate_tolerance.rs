//! Ablation A1: tolerance sweep on the native solver — how NFE, the error
//! regularizer R_E and the stiffness accumulator scale with rtol/atol.
//! (The paper fixes tol = 1.4e-8; DESIGN.md §4 documents our looser
//! default, and this bench quantifies the trade.)
use regnde::solvers::{problems, solve, OdeOptions};
use regnde::util::tablefmt::Table;

fn main() {
    let mut t = Table::new(
        "Ablation — tolerance sweep (native Tsit5 on the cubic spiral)",
        &["rtol=atol", "NFE", "accepted", "rejected", "R_E", "R_S/step"],
    );
    for tol in [1e-2, 1e-3, 1e-4, 1e-5, 1e-6, 1e-7, 1e-8] {
        let opts = OdeOptions {
            rtol: tol,
            atol: tol,
            ..Default::default()
        };
        let out = solve(problems::spiral_ode, &[2.0, 0.0], 0.0, 1.5, &opts);
        assert!(out.success);
        t.row(vec![
            format!("{tol:.0e}"),
            format!("{}", out.stats.nfe),
            format!("{}", out.stats.naccept),
            format!("{}", out.stats.nreject),
            format!("{:.3e}", out.stats.r_e),
            format!("{:.2}", out.stats.r_s / out.stats.naccept as f64),
        ]);
    }
    println!("{}", t.render());
    println!("expected shape: NFE grows ~tol^(-1/5) (5th-order method); R_E shrinks with tol");
}
