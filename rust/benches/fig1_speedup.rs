//! Regenerates paper Figure 1: aggregate training and prediction speedups
//! of the regularized models over their unregularized baselines, across
//! all four experiments.
use regnde::bench::{render_speedups, run_grid, BenchConfig};
use regnde::coordinator::Method;

fn main() {
    let cfg = BenchConfig::from_env(2, 6);
    let ode = ["vanilla", "srnode", "ernode"].map(|m| Method::parse(m).unwrap());
    let sde = Method::table_grid_sde();
    let mut speedups = Vec::new();
    for (exp, methods, is_sde) in [
        ("mnist-node", &ode[..], false),
        ("latent-ode", &ode[..], false),
        ("spiral-nsde", &sde[..], true),
        ("mnist-nsde", &sde[..], true),
    ] {
        eprintln!("== {exp} ==");
        let grid = run_grid(exp, methods, &cfg).expect("bench failed");
        println!("{}", render_speedups(&format!("Figure 1 — {exp}"), &grid, is_sde));
        let base_t = grid[0].summary(|r| r.train_time_s).mean;
        let base_p = grid[0].summary(|r| r.predict_time_s).mean;
        for m in grid.iter().skip(1) {
            speedups.push((
                base_t / m.summary(|r| r.train_time_s).mean.max(1e-9),
                base_p / m.summary(|r| r.predict_time_s).mean.max(1e-9),
            ));
        }
    }
    let n = speedups.len() as f64;
    let (st, sp): (f64, f64) = speedups
        .iter()
        .fold((0.0, 0.0), |(a, b), (t, p)| (a + t, b + p));
    println!(
        "AVERAGE over all regularized models: train {:.2}x, predict {:.2}x \
         (paper Figure 1: 1.45x train, 1.84x predict for best models)",
        st / n,
        sp / n
    );
}
