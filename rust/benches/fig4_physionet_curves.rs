//! Regenerates paper Figure 4: NFE and training loss vs epoch for the
//! Physionet Latent ODE (regularized variants bound NFE; vanilla grows).
use regnde::bench::{render_series, run_grid, BenchConfig};
use regnde::coordinator::Method;

fn main() {
    let cfg = BenchConfig::from_env(5, 5);
    let methods = ["vanilla", "steer", "srnode", "ernode"]
        .map(|m| Method::parse(m).unwrap());
    let grid = run_grid("latent-ode", &methods, &cfg).expect("bench failed");
    println!(
        "{}",
        render_series(
            "Figure 4 — Physionet Latent ODE: NFE and train loss vs epoch \
             (metric column = masked MSE)",
            &grid,
            false,
        )
    );
    println!("paper shape: ER/SR bound NFE < 300 vs ~700 unregularized/STEER");
}
