//! Regenerates paper Table 1: MNIST image classification with Neural ODE —
//! the full method grid (Vanilla / STEER / TayNODE / SRNODE / ERNODE and
//! compositions) with accuracy, train time, prediction time and NFE.
//! Scale via REGNDE_BENCH_{EPOCHS,ITERS,SEEDS}.
use regnde::bench::{render_table, run_grid, BenchConfig};
use regnde::coordinator::Method;

fn main() {
    let cfg = BenchConfig::from_env(3, 8);
    let grid = run_grid("mnist-node", &Method::table_grid_ode(), &cfg)
        .expect("bench failed — run `make artifacts` first");
    println!(
        "{}",
        render_table(
            "Table 1 — MNIST Image Classification using Neural ODE (testbed scale)",
            &grid,
            false,
            true,
        )
    );
    println!("paper reference: ERNODE 1.20x train / 1.57x predict speedup, NFE 253 -> 177");
}
