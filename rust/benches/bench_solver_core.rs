//! Solver-core microbenchmark: single-trajectory stepping rate and
//! ensemble integration throughput (serial vs thread-pooled).
//!
//! This is the perf anchor for the allocation-free solver rewrite: it
//! times the exact hot loops behind ground-truth generation and the
//! tolerance/ablation benches, and emits `BENCH_solver_core.json` at the
//! repo root (schema documented in rust/DESIGN.md §Perf) so the perf
//! trajectory is tracked PR over PR.
//!
//! Scale knobs (env):
//!   REGNDE_BENCH_SEEDS   measurement repetitions per case (default 3)
//!   REGNDE_BENCH_TRAJ    ensemble size                    (default 256)
//!   REGNDE_BENCH_POINTS  SDE save-grid length             (default 30)
use std::time::Instant;

use regnde::data::spiral::uniform_grid;
use regnde::solvers::{
    problems, sde_ensemble_moments, solve, EnsembleOptions, OdeSystem, Saveat, SolveOptions,
    StepBudget, Tableau, Taping,
};
use regnde::util::cli::env_usize;
use regnde::util::json::{obj, Json};
use regnde::util::tablefmt::Table;
use regnde::util::threadpool::default_workers;

/// Best-of-`reps` single-trajectory stepping rate for one ODE case.
fn single_case(
    name: &str,
    tableau: Tableau,
    f: impl Fn(&[f64], f64, &mut [f64]) + Copy,
    z0: &[f64],
    t1: f64,
    reps: usize,
) -> (Json, Vec<String>) {
    let opts = SolveOptions::new()
        .with_tableau(tableau)
        .with_tolerance(1e-6)
        .with_budget(StepBudget::PerSegment(10_000_000));
    let mut best_steps_per_sec = 0.0f64;
    let mut attempts = 0u64;
    let mut nfe = 0u64;
    for _ in 0..reps {
        // Repeat the solve enough times that the timer resolution is
        // negligible relative to the measured interval.
        let inner = 50;
        let t0 = Instant::now();
        let mut total_attempts = 0u64;
        let mut total_nfe = 0u64;
        for _ in 0..inner {
            let mut sys = OdeSystem(f);
            let (_, out) = solve(
                &mut sys,
                z0,
                Saveat::Span { t0: 0.0, t1 },
                &opts,
                None,
                Taping::Off,
                &mut [],
            );
            let out = out.unwrap_or_else(|e| panic!("{name} solve failed: {e}"));
            total_attempts += out.stats.attempts();
            total_nfe += out.stats.nfe;
            std::hint::black_box(&out.z);
        }
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        best_steps_per_sec = best_steps_per_sec.max(total_attempts as f64 / secs);
        attempts = total_attempts / inner;
        nfe = total_nfe / inner;
    }
    let row = vec![
        name.to_string(),
        format!("{attempts}"),
        format!("{nfe}"),
        format!("{best_steps_per_sec:.0}"),
    ];
    let j = obj([
        ("case", Json::from(name)),
        ("attempts_per_solve", Json::from(attempts as f64)),
        ("nfe_per_solve", Json::from(nfe as f64)),
        ("steps_per_sec", Json::from(best_steps_per_sec)),
        ("rtol", Json::from(1e-6)),
    ]);
    (j, row)
}

fn main() {
    let reps = env_usize("REGNDE_BENCH_SEEDS", 3).max(1);
    let n_traj = env_usize("REGNDE_BENCH_TRAJ", 256).max(2);
    let t_points = env_usize("REGNDE_BENCH_POINTS", 30).max(2);
    let workers = default_workers();

    // ---- single-trajectory stepping rate ------------------------------
    let mut table = Table::new(
        "Solver core — single-trajectory stepping rate (best of reps)",
        &["case", "attempts/solve", "NFE/solve", "steps/sec"],
    );
    let mut singles: Vec<Json> = Vec::new();
    for (j, row) in [
        single_case(
            "spiral_ode/tsit5",
            Tableau::tsit5(),
            problems::spiral_ode,
            &[2.0, 0.0],
            1.5,
            reps,
        ),
        single_case(
            "spiral_ode/dopri5",
            Tableau::dopri5(),
            problems::spiral_ode,
            &[2.0, 0.0],
            1.5,
            reps,
        ),
        single_case(
            "exp_decay_d16/tsit5",
            Tableau::tsit5(),
            |z: &[f64], _t: f64, dz: &mut [f64]| {
                for i in 0..z.len() {
                    dz[i] = -z[i];
                }
            },
            &[1.0; 16],
            5.0,
            reps,
        ),
    ] {
        singles.push(j);
        table.row(row);
    }
    println!("{}", table.render());

    // ---- ensemble throughput: serial vs pooled ------------------------
    let ts = uniform_grid(t_points, 1.0);
    let opts = SolveOptions::new().with_tolerance(1e-3);
    let run_ens = |eopts: &EnsembleOptions| -> f64 {
        let mut best = 0.0f64;
        for _ in 0..reps {
            let t0 = Instant::now();
            let m = sde_ensemble_moments(
                &problems::spiral_sde_drift,
                &problems::spiral_sde_diffusion,
                &[1.0, 1.0],
                &ts,
                n_traj,
                42,
                &opts,
                eopts,
            );
            assert!(m.success());
            std::hint::black_box(&m.mu);
            best = best.max(n_traj as f64 / t0.elapsed().as_secs_f64().max(1e-9));
        }
        best
    };
    let serial = run_ens(&EnsembleOptions::serial());
    let pooled = run_ens(&EnsembleOptions {
        workers,
        ..Default::default()
    });
    let speedup = pooled / serial.max(1e-9);

    let mut etable = Table::new(
        "Solver core — spiral DSDE ensemble throughput (trajectories/sec)",
        &["schedule", "workers", "traj/sec", "speedup"],
    );
    etable.row(vec![
        "serial".into(),
        "1".into(),
        format!("{serial:.1}"),
        "1.00x".into(),
    ]);
    etable.row(vec![
        "pooled".into(),
        format!("{workers}"),
        format!("{pooled:.1}"),
        format!("{speedup:.2}x"),
    ]);
    println!("{}", etable.render());
    println!(
        "({n_traj} trajectories x {t_points} save points; identical bits serial vs pooled)"
    );

    // ---- emit BENCH_solver_core.json at the repo root -----------------
    let report = obj([
        ("schema", Json::from("bench_solver_core/v1")),
        ("single_trajectory", Json::Arr(singles)),
        (
            "ensemble",
            obj([
                ("problem", Json::from("spiral_dsde")),
                ("n_traj", Json::from(n_traj)),
                ("t_points", Json::from(t_points)),
                ("workers", Json::from(workers)),
                ("serial_traj_per_sec", Json::from(serial)),
                ("pooled_traj_per_sec", Json::from(pooled)),
                ("speedup", Json::from(speedup)),
            ]),
        ),
        (
            "meta",
            obj([
                ("reps", Json::from(reps)),
                (
                    "available_parallelism",
                    Json::from(
                        std::thread::available_parallelism()
                            .map(|n| n.get())
                            .unwrap_or(1),
                    ),
                ),
            ]),
        ),
    ]);
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_solver_core.json");
    std::fs::write(&path, report.to_string_pretty()).expect("write bench report");
    println!("wrote {}", path.display());
}
