//! Solver-core microbenchmark: single-trajectory stepping rate, the
//! batch-width sweep over the vectorized MLP kernels (scalar-fallback vs
//! kernel ablation on identical call paths), and ensemble integration
//! throughput (serial vs thread-pooled).
//!
//! This is the perf anchor for the allocation-free solver rewrite and
//! the batched-kernel hot path: it times the exact loops behind
//! ground-truth generation, native training and serving, and emits
//! `BENCH_solver_core.json` at the repo root (schema documented in
//! rust/DESIGN.md §Perf) so the perf trajectory is tracked PR over PR.
//!
//! Scale knobs (env):
//!   REGNDE_BENCH_SEEDS   measurement repetitions per case (default 3)
//!   REGNDE_BENCH_TRAJ    ensemble size                    (default 256)
//!   REGNDE_BENCH_POINTS  SDE save-grid length             (default 30)
use std::time::Instant;

use regnde::data::spiral::uniform_grid;
use regnde::models::{kernels, Mlp};
use regnde::solvers::{
    problems, sde_ensemble_moments, solve, EnsembleOptions, OdeSystem, Saveat, SolveOptions,
    StepBudget, Tableau, Taping,
};
use regnde::util::cli::env_usize;
use regnde::util::json::{obj, Json};
use regnde::util::rng::Rng;
use regnde::util::tablefmt::Table;
use regnde::util::threadpool::default_workers;

/// Batch-sweep MLP shape: the MNIST-class dynamics block scaled to a
/// 64-wide hidden layer (the ISSUE's sweep point).
const SWEEP_DIMS: [usize; 3] = [16, 64, 16];

/// GEMM flops per NFE per row: forward + two matmuls (`2·Σ inᵢ·outᵢ`);
/// tanh cost excluded — this is a GEMM-flop rate, not a full-op count.
const FLOPS_PER_ROW_NFE: f64 = 2.0 * (16.0 * 64.0 + 64.0 * 16.0);

/// One batch-width sweep point: drive `rows` copies of the MLP vector
/// field through the adaptive stepper twice — scalar-fallback leg, then
/// kernel leg — on the exact same call path (`Mlp::forward_batch` +
/// fused `rk_combine`, toggled by `kernels::set_scalar_fallback`).
fn batch_sweep_case(rows: usize, reps: usize) -> (Json, Vec<String>) {
    let mlp = Mlp::new(&SWEEP_DIMS);
    let mut p32 = vec![0.0f32; mlp.n_params()];
    mlp.init(&mut Rng::new(77), &mut p32);
    let theta: Vec<f64> = p32.iter().map(|&v| v as f64 * 0.5).collect();
    let mut rng = Rng::new(78);
    let z0: Vec<f64> = (0..rows * SWEEP_DIMS[0]).map(|_| rng.range(-1.0, 1.0)).collect();
    let opts = SolveOptions::new()
        .with_tolerance(1e-6)
        .with_budget(StepBudget::PerSegment(10_000_000));
    // Scale inner repeats down with batch width so every sweep point
    // measures a comparable wall-clock interval.
    let inner = (512 / rows).max(8);

    let mut leg = |scalar: bool| -> (f64, f64) {
        kernels::set_scalar_fallback(scalar);
        let mut scratch = mlp.batch_scratch(rows);
        let mut best_rate = 0.0f64;
        let mut best_gflops = 0.0f64;
        for _ in 0..reps {
            let t0 = Instant::now();
            let mut attempts = 0u64;
            let mut nfe = 0u64;
            for _ in 0..inner {
                let mut sys = OdeSystem(|z: &[f64], _t: f64, dz: &mut [f64]| {
                    mlp.forward_batch(&theta, z, dz, &mut scratch)
                });
                let (_, out) = solve(
                    &mut sys,
                    &z0,
                    Saveat::Span { t0: 0.0, t1: 1.5 },
                    &opts,
                    None,
                    Taping::Off,
                    &mut [],
                );
                let out = out.unwrap_or_else(|e| panic!("batch sweep solve failed: {e}"));
                attempts += out.stats.attempts();
                nfe += out.stats.nfe;
                std::hint::black_box(&out.z);
            }
            let secs = t0.elapsed().as_secs_f64().max(1e-9);
            best_rate = best_rate.max(attempts as f64 / secs);
            let flops = nfe as f64 * rows as f64 * FLOPS_PER_ROW_NFE;
            best_gflops = best_gflops.max(flops / secs / 1e9);
        }
        kernels::set_scalar_fallback(false);
        (best_rate, best_gflops)
    };
    let (scalar_rate, _) = leg(true);
    let (kernel_rate, kernel_gflops) = leg(false);
    let speedup = kernel_rate / scalar_rate.max(1e-9);

    let row = vec![
        format!("{rows}"),
        format!("{scalar_rate:.0}"),
        format!("{kernel_rate:.0}"),
        format!("{speedup:.2}x"),
        format!("{kernel_gflops:.2}"),
    ];
    let j = obj([
        ("rows", Json::from(rows)),
        ("hidden", Json::from(SWEEP_DIMS[1])),
        ("scalar_steps_per_sec", Json::from(scalar_rate)),
        ("kernel_steps_per_sec", Json::from(kernel_rate)),
        ("speedup", Json::from(speedup)),
        ("kernel_gflops", Json::from(kernel_gflops)),
    ]);
    (j, row)
}

/// Best-of-`reps` single-trajectory stepping rate for one ODE case.
fn single_case(
    name: &str,
    tableau: Tableau,
    f: impl Fn(&[f64], f64, &mut [f64]) + Copy,
    z0: &[f64],
    t1: f64,
    reps: usize,
) -> (Json, Vec<String>) {
    let opts = SolveOptions::new()
        .with_tableau(tableau)
        .with_tolerance(1e-6)
        .with_budget(StepBudget::PerSegment(10_000_000));
    let mut best_steps_per_sec = 0.0f64;
    let mut attempts = 0u64;
    let mut nfe = 0u64;
    for _ in 0..reps {
        // Repeat the solve enough times that the timer resolution is
        // negligible relative to the measured interval.
        let inner = 50;
        let t0 = Instant::now();
        let mut total_attempts = 0u64;
        let mut total_nfe = 0u64;
        for _ in 0..inner {
            let mut sys = OdeSystem(f);
            let (_, out) = solve(
                &mut sys,
                z0,
                Saveat::Span { t0: 0.0, t1 },
                &opts,
                None,
                Taping::Off,
                &mut [],
            );
            let out = out.unwrap_or_else(|e| panic!("{name} solve failed: {e}"));
            total_attempts += out.stats.attempts();
            total_nfe += out.stats.nfe;
            std::hint::black_box(&out.z);
        }
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        best_steps_per_sec = best_steps_per_sec.max(total_attempts as f64 / secs);
        attempts = total_attempts / inner;
        nfe = total_nfe / inner;
    }
    let row = vec![
        name.to_string(),
        format!("{attempts}"),
        format!("{nfe}"),
        format!("{best_steps_per_sec:.0}"),
    ];
    let j = obj([
        ("case", Json::from(name)),
        ("attempts_per_solve", Json::from(attempts as f64)),
        ("nfe_per_solve", Json::from(nfe as f64)),
        ("steps_per_sec", Json::from(best_steps_per_sec)),
        ("rtol", Json::from(1e-6)),
    ]);
    (j, row)
}

fn main() {
    let reps = env_usize("REGNDE_BENCH_SEEDS", 3).max(1);
    let n_traj = env_usize("REGNDE_BENCH_TRAJ", 256).max(2);
    let t_points = env_usize("REGNDE_BENCH_POINTS", 30).max(2);
    let workers = default_workers();

    // ---- single-trajectory stepping rate ------------------------------
    let mut table = Table::new(
        "Solver core — single-trajectory stepping rate (best of reps)",
        &["case", "attempts/solve", "NFE/solve", "steps/sec"],
    );
    let mut singles: Vec<Json> = Vec::new();
    for (j, row) in [
        single_case(
            "spiral_ode/tsit5",
            Tableau::tsit5(),
            problems::spiral_ode,
            &[2.0, 0.0],
            1.5,
            reps,
        ),
        single_case(
            "spiral_ode/dopri5",
            Tableau::dopri5(),
            problems::spiral_ode,
            &[2.0, 0.0],
            1.5,
            reps,
        ),
        single_case(
            "exp_decay_d16/tsit5",
            Tableau::tsit5(),
            |z: &[f64], _t: f64, dz: &mut [f64]| {
                for i in 0..z.len() {
                    dz[i] = -z[i];
                }
            },
            &[1.0; 16],
            5.0,
            reps,
        ),
    ] {
        singles.push(j);
        table.row(row);
    }
    println!("{}", table.render());

    // ---- batch-width sweep: scalar vs kernel ablation -----------------
    let mut btable = Table::new(
        "Solver core — MLP [16,64,16] batch sweep (scalar vs kernel, steps/sec)",
        &["rows", "scalar", "kernel", "speedup", "kernel GFLOP/s"],
    );
    let mut sweep: Vec<Json> = Vec::new();
    for rows in [1usize, 8, 32, 128] {
        let (j, row) = batch_sweep_case(rows, reps);
        sweep.push(j);
        btable.row(row);
    }
    println!("{}", btable.render());

    // ---- ensemble throughput: serial vs pooled ------------------------
    let ts = uniform_grid(t_points, 1.0);
    let opts = SolveOptions::new().with_tolerance(1e-3);
    let run_ens = |eopts: &EnsembleOptions| -> f64 {
        let mut best = 0.0f64;
        for _ in 0..reps {
            let t0 = Instant::now();
            let m = sde_ensemble_moments(
                &problems::spiral_sde_drift,
                &problems::spiral_sde_diffusion,
                &[1.0, 1.0],
                &ts,
                n_traj,
                42,
                &opts,
                eopts,
            );
            assert!(m.success());
            std::hint::black_box(&m.mu);
            best = best.max(n_traj as f64 / t0.elapsed().as_secs_f64().max(1e-9));
        }
        best
    };
    let serial = run_ens(&EnsembleOptions::serial());
    let pooled = run_ens(&EnsembleOptions {
        workers,
        ..Default::default()
    });
    let speedup = pooled / serial.max(1e-9);

    let mut etable = Table::new(
        "Solver core — spiral DSDE ensemble throughput (trajectories/sec)",
        &["schedule", "workers", "traj/sec", "speedup"],
    );
    etable.row(vec![
        "serial".into(),
        "1".into(),
        format!("{serial:.1}"),
        "1.00x".into(),
    ]);
    etable.row(vec![
        "pooled".into(),
        format!("{workers}"),
        format!("{pooled:.1}"),
        format!("{speedup:.2}x"),
    ]);
    println!("{}", etable.render());
    println!(
        "({n_traj} trajectories x {t_points} save points; identical bits serial vs pooled)"
    );

    // ---- emit BENCH_solver_core.json at the repo root -----------------
    let report = obj([
        ("schema", Json::from("bench_solver_core/v2")),
        ("single_trajectory", Json::Arr(singles)),
        ("batch_sweep", Json::Arr(sweep)),
        (
            "ensemble",
            obj([
                ("problem", Json::from("spiral_dsde")),
                ("n_traj", Json::from(n_traj)),
                ("t_points", Json::from(t_points)),
                ("workers", Json::from(workers)),
                ("serial_traj_per_sec", Json::from(serial)),
                ("pooled_traj_per_sec", Json::from(pooled)),
                ("speedup", Json::from(speedup)),
            ]),
        ),
        (
            "meta",
            obj([
                ("reps", Json::from(reps)),
                (
                    "available_parallelism",
                    Json::from(
                        std::thread::available_parallelism()
                            .map(|n| n.get())
                            .unwrap_or(1),
                    ),
                ),
            ]),
        ),
    ]);
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_solver_core.json");
    std::fs::write(&path, report.to_string_pretty()).expect("write bench report");
    println!("wrote {}", path.display());
}
