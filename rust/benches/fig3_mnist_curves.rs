//! Regenerates paper Figure 3: NFE and training accuracy vs epoch for the
//! MNIST Neural ODE method grid (per-epoch series, averaged over seeds).
use regnde::bench::{render_series, run_grid, BenchConfig};
use regnde::coordinator::Method;

fn main() {
    let cfg = BenchConfig::from_env(5, 6);
    let methods = ["vanilla", "steer", "srnode", "ernode", "srnode+ernode"]
        .map(|m| Method::parse(m).unwrap());
    let grid = run_grid("mnist-node", &methods, &cfg).expect("bench failed");
    println!(
        "{}",
        render_series(
            "Figure 3 — MNIST NODE: NFE and train accuracy vs epoch \
             (metric column = accuracy)",
            &grid,
            false,
        )
    );
    println!("paper shape: ERNODE keeps NFE lowest; SR+ER stabilizes training");
}
