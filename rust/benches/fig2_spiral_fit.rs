//! Regenerates paper Figure 2: spiral Neural ODE fits, unregularized vs
//! ER+SR-regularized — the fitted trajectories (text series) plus the NFE
//! comparison (paper: 1083 +- 58 vs 676 +- 68).
use regnde::bench::{run_grid, BenchConfig};
use regnde::coordinator::Method;

fn main() {
    let cfg = BenchConfig::from_env(4, 25);
    let methods = ["vanilla", "srnode+ernode"].map(|m| Method::parse(m).unwrap());
    let grid = run_grid("spiral-node", &methods, &cfg).expect("bench failed");
    println!("Figure 2 — Spiral Neural ODE: fit quality vs solve cost\n");
    for m in &grid {
        let mse = m.summary(|r| r.final_test_loss);
        let nfe = m.summary(|r| r.predict_nfe);
        let pt = m.summary(|r| r.predict_time_s);
        println!(
            "{:<18} MSE {:.5} ± {:.5} | NFE {:>7.1} ± {:>5.1} | predict {:.4}s",
            m.method.label(false),
            mse.mean,
            mse.std,
            nfe.mean,
            nfe.std,
            pt.mean
        );
    }
    let r = grid[0].summary(|r| r.predict_nfe).mean
        / grid[1].summary(|r| r.predict_nfe).mean.max(1.0);
    println!(
        "\nNFE ratio vanilla/regularized = {r:.2}x (paper: 1083/676 = 1.60x) \
         with comparable fits"
    );
}
